"""AOT compile path: lower every serving graph to HLO text artifacts.

Run once by ``make artifacts``; Python never executes at serving time.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all shapes static; the Rust coordinator buckets requests):

  fn_smoke.hlo.txt                  matmul+2 smoke test for the runtime
  attn_native_l{L}_d64.hlo.txt      flash baseline  (q,k,v f32[L,64]) -> o
  attn_dma_l{L}_d64.hlo.txt         DMA pipeline    (q,k,v f32[L,64]) -> o
  quant_dual_l128_d64.hlo.txt       fused dual quantization, 5 outputs
  prefill_{mode}_l{L}.hlo.txt       weights..., tokens i32[L] ->
                                    (logits f32[L,V], k/v caches)
  decode_b{B}.hlo.txt               weights..., tokens i32[B], caches, pos
                                    -> (logits f32[B,V], caches')
  eval_{mode}_l{L}_b{B}.hlo.txt     weights..., tokens i32[B,L] -> logits
  weights.bin                       flat f32 tensors (layout: see meta)
  model_meta.json                   config, signatures, token conventions
  train_history.json                build-time training loss curve
  eval_python.json                  python-side Table-3 cross-check
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tasks
from .kernels import dma_attention as dak
from .kernels import flash as fl
from .kernels import quant_fused as qf

CACHE_LEN = 320          # decode bucket cache capacity
PREFILL_LENS = (64, 128, 256)
DECODE_BATCHES = (1, 2, 4)
ATTN_LENS = (128, 512)
ATTN_D = 64
EVAL_SHAPES = ((8, 96), (8, 224))   # (batch, length) Table-3 buckets


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(tree):
    """JSON-able signature of a pytree of ShapeDtypeStruct/arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    return [{"shape": list(x.shape), "dtype": str(x.dtype)} for x in leaves]


class Exporter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.index = {}

    def export(self, name, fn, *example_args):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_sig = _sig(jax.eval_shape(fn, *example_args))
        self.index[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _sig(example_args),
            "outputs": out_sig,
        }
        print(f"  exported {name:28s} ({len(text)/1e6:.2f} MB, "
              f"{time.time()-t0:.1f}s)")


def write_weights_bin(path, flat):
    """Binary weight format shared with rust/src/model/weights.rs:

    magic 'DMAW' u32, version u32, count u32, then per tensor:
    name_len u32, name bytes, ndim u32, dims u32..., f32 data (LE).
    """
    with open(path, "wb") as f:
        f.write(b"DMAW")
        f.write(struct.pack("<II", 1, len(flat)))
        for name, arr in flat:
            a = np.asarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", a.ndim))
            f.write(struct.pack(f"<{a.ndim}I", *a.shape))
            f.write(a.tobytes())


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--train-len", type=int, default=256)
    ap.add_argument("--skip-train", action="store_true",
                    help="random weights (fast iteration)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.ModelConfig()
    ex = Exporter(args.out_dir)

    # ------------------------------------------------------------------
    # 0. Runtime smoke artifact (matches the /opt/xla-example contract).
    # ------------------------------------------------------------------
    def fn_smoke(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    ex.export("fn_smoke", fn_smoke, spec((2, 2)), spec((2, 2)))

    # ------------------------------------------------------------------
    # 1. Attention micro-kernels (paper Tables 4/5 driving functions).
    # ------------------------------------------------------------------
    for L in ATTN_LENS:
        ex.export(
            f"attn_native_l{L}_d{ATTN_D}",
            lambda q, k, v: fl.flash_attention(q, k, v, causal=True),
            spec((L, ATTN_D)), spec((L, ATTN_D)), spec((L, ATTN_D)),
        )
        ex.export(
            f"attn_dma_l{L}_d{ATTN_D}",
            lambda q, k, v: dak.dma_attention(
                q, k, v, bm=64, bn=64, diag=128, sink=128, causal=True),
            spec((L, ATTN_D)), spec((L, ATTN_D)), spec((L, ATTN_D)),
        )
    ex.export(
        "quant_dual_l128_d64",
        lambda x: qf.dual_quant(x, is_query=True),
        spec((128, ATTN_D)),
    )

    # ------------------------------------------------------------------
    # 2. Train the small model on the synthetic long-context mixture.
    # ------------------------------------------------------------------
    t0 = time.time()
    if args.skip_train:
        print("  [skip-train] using random weights")
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        history = []
    else:
        print(f"  training {args.steps} steps "
              f"(B={args.train_batch}, L={args.train_len}) ...")
        params, history = M.train(
            cfg, steps=args.steps, batch=args.train_batch,
            length=args.train_len, seed=args.seed)
    print(f"  training done in {time.time()-t0:.0f}s")
    with open(os.path.join(args.out_dir, "train_history.json"), "w") as f:
        json.dump({"loss": history, "steps": len(history),
                   "batch": args.train_batch, "length": args.train_len}, f)

    flat = M.flatten_params(params, cfg)
    write_weights_bin(os.path.join(args.out_dir, "weights.bin"), flat)
    wspecs = [spec(a.shape) for _, a in flat]

    # ------------------------------------------------------------------
    # 3. Serving graphs: prefill / decode with explicit KV-cache I/O.
    #    Weights are HLO parameters 0..N-1 (layout contract in meta).
    # ------------------------------------------------------------------
    def with_weights(fn):
        def wrapped(weights, *rest):
            p = M.unflatten_params(weights, cfg)
            return fn(p, *rest)
        return wrapped

    for L in PREFILL_LENS:
        for mode in ("native", "dma"):
            ex.export(
                f"prefill_{mode}_l{L}",
                with_weights(lambda p, toks, _mode=mode: M.prefill(
                    p, toks, cfg, mode=_mode)),
                wspecs, spec((L,), jnp.int32),
            )

    kv_spec = spec((cfg.n_layers, cfg.n_kv_heads, CACHE_LEN, cfg.d_head))
    for B in DECODE_BATCHES:
        ex.export(
            f"decode_b{B}",
            with_weights(lambda p, toks, kc, vc, pos: M.decode_step_batch(
                p, toks, kc, vc, pos, cfg)),
            wspecs,
            spec((B,), jnp.int32),
            spec((cfg.n_layers, B, cfg.n_kv_heads, CACHE_LEN, cfg.d_head)),
            spec((cfg.n_layers, B, cfg.n_kv_heads, CACHE_LEN, cfg.d_head)),
            spec((B,), jnp.int32),
        )

    # ------------------------------------------------------------------
    # 4. Evaluation graphs (Table 3 proxy): batched full-sequence logits.
    # ------------------------------------------------------------------
    for B, L in EVAL_SHAPES:
        for mode in ("native", "dma"):
            ex.export(
                f"eval_{mode}_l{L}_b{B}",
                with_weights(lambda p, toks, _mode=mode: M.forward_batch(
                    p, toks, cfg, mode=_mode)),
                wspecs, spec((B, L), jnp.int32),
            )

    # ------------------------------------------------------------------
    # 5. Python-side Table-3 cross-check (also recorded in EXPERIMENTS.md)
    # ------------------------------------------------------------------
    eval_rows = []
    if not args.skip_train:
        for task in tasks.TASK_NAMES:
            for _, L in EVAL_SHAPES:
                row = {"task": f"{task}_{L}"}
                for mode in ("native", "dma"):
                    row[mode] = M.eval_accuracy(
                        params, cfg, mode, task, L, n=8, seed=1)
                eval_rows.append(row)
                print(f"  eval {row['task']:16s} native={row['native']:.3f} "
                      f"dma={row['dma']:.3f}")
    with open(os.path.join(args.out_dir, "eval_python.json"), "w") as f:
        json.dump(eval_rows, f, indent=1)

    # ------------------------------------------------------------------
    # 6. Metadata contract for the Rust side.
    # ------------------------------------------------------------------
    meta = {
        "model": cfg.as_dict(),
        "param_order": [name for name, _ in flat],
        "param_note": M.PARAM_ORDER_NOTE,
        "cache_len": CACHE_LEN,
        "prefill_lens": list(PREFILL_LENS),
        "decode_batches": list(DECODE_BATCHES),
        "attn_lens": list(ATTN_LENS),
        "attn_d": ATTN_D,
        "eval_shapes": [list(s) for s in EVAL_SHAPES],
        "tokens": {"PAD": tasks.PAD, "BOS": tasks.BOS, "SEP": tasks.SEP,
                   "QRY": tasks.QRY, "MRK": tasks.MRK, "EOS": tasks.EOS,
                   "PAYLOAD_START": tasks.PAYLOAD_START,
                   "VOCAB": tasks.VOCAB},
        # Per-layer KV-cache precision policy the serving side defaults
        # to (rust MetaConfig checks: 1 entry broadcasts, else one per
        # layer). Derived from the attention windows the model was built
        # around — pages inside the sink/diag windows decode high.
        "kv_precision_policy": {
            "layers": [{"sink": cfg.sink, "diag": cfg.diag}
                       for _ in range(cfg.n_layers)],
        },
        "artifacts": ex.index,
    }
    with open(os.path.join(args.out_dir, "model_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  wrote model_meta.json with {len(ex.index)} artifacts")


if __name__ == "__main__":
    main()
