"""Synthetic long-context tasks (LongBench proxy).

LongBench itself cannot be used offline, and the paper's accuracy claim
(Table 3) is *relative* — DMA attention matches native attention on the
same model. We therefore train a small decoder on synthetic tasks that
exercise exactly the capability low-bit attention endangers: retrieving
information far from the diagonal of the attention matrix.

Token conventions (mirrored by ``rust/src/eval``; see model_meta.json):

  0 PAD   1 BOS   2 SEP   3 QRY   4 MRK   5 EOS   6.. payload vocab

Tasks
-----
copy       BOS w1..wn SEP w1..wn          — score on the echoed half
needle     BOS noise.. MRK key val noise.. QRY key -> val
                                          — score on the answer token
induction  a repeating random motif       — score on repeats after the
                                            first occurrence

Each generator returns ``(tokens[L], mask[L])`` where ``mask[t] = 1`` iff
position ``t``'s *target* (``tokens[t+1]``) is scored.
"""

from __future__ import annotations

import numpy as np

PAD, BOS, SEP, QRY, MRK, EOS = 0, 1, 2, 3, 4, 5
PAYLOAD_START = 6
VOCAB = 64

TASK_NAMES = ("copy", "needle", "induction")


def _payload(rng, n):
    return rng.integers(PAYLOAD_START, VOCAB, size=n)


def gen_copy(rng, length, n=None):
    """BOS w1..wn SEP w1..wn with a RANDOM payload length.

    Randomizing ``n`` is essential: with a fixed n the model can solve
    the task by position (attend exactly n+1 back) instead of content,
    which silently fails at other evaluation lengths.
    """
    n_max = (length - 2) // 2
    if n is None:
        n = int(rng.integers(min(8, n_max), n_max + 1))
    w = _payload(rng, n)
    toks = np.full(length, PAD, dtype=np.int32)
    toks[0] = BOS
    toks[1 : 1 + n] = w
    toks[1 + n] = SEP
    toks[2 + n : 2 + 2 * n] = w
    mask = np.zeros(length, dtype=np.float32)
    # Position t is scored if tokens[t+1] is part of the echoed copy.
    mask[1 + n : 1 + 2 * n] = 1.0
    return toks, mask


def gen_needle(rng, length, n_pairs=2):
    """Multiple (MRK key val) needles buried in noise; all are queried at
    the end (``QRY key -> val`` each), giving several supervised
    positions per example so the task is not gradient-starved next to
    copy's ~L/2 masked positions."""
    toks = np.full(length, PAD, dtype=np.int32)
    toks[0] = BOS
    noise = _payload(rng, length)
    toks[1:] = noise[1:]
    # Distinct keys, sampled without replacement.
    keys = rng.choice(np.arange(PAYLOAD_START, VOCAB), size=n_pairs,
                      replace=False)
    vals = _payload(rng, n_pairs)
    tail = 3 * n_pairs  # QRY key val per pair
    # Needles sit in the first half — far from the final queries.
    positions = sorted(
        rng.choice(np.arange(2, max(3, length // 2), 3), size=n_pairs,
                   replace=False)
    )
    for p_, key, val in zip(positions, keys, vals):
        toks[p_] = MRK
        toks[p_ + 1] = key
        toks[p_ + 2] = val
    # Keys must not occur elsewhere by accident.
    protect = {p_ + 1 for p_ in positions}
    for key in keys:
        clash = toks == key
        for pp in protect:
            clash[pp] = False
        clash[length - tail:] = False
        toks[clash] = PAYLOAD_START + (int(key) - PAYLOAD_START + 1) % (
            VOCAB - PAYLOAD_START)
    mask = np.zeros(length, dtype=np.float32)
    base = length - tail
    for i, (key, val) in enumerate(zip(keys, vals)):
        toks[base + 3 * i] = QRY
        toks[base + 3 * i + 1] = key
        toks[base + 3 * i + 2] = val
        mask[base + 3 * i + 1] = NEEDLE_WEIGHT  # target: the answer val
    return toks, mask


def gen_induction(rng, length):
    period = int(rng.integers(4, 9))
    motif = _payload(rng, period)
    reps = -(-length // period)
    toks = np.tile(motif, reps)[:length].astype(np.int32)
    toks[0] = BOS
    mask = np.zeros(length, dtype=np.float32)
    mask[period : length - 1] = 1.0  # everything after the first motif
    return toks, mask


GENERATORS = {"copy": gen_copy, "needle": gen_needle, "induction": gen_induction}


# Sampling weights for the training mixture: needle is the hardest
# retrieval task (and the one low-bit attention endangers most), so it
# gets extra weight.
TASK_WEIGHTS = {"copy": 1, "needle": 2, "induction": 1}

# Loss weight on needle answer positions: a needle example supervises
# only ~2 positions vs ~L/2 for copy; this rebalances the gradient
# under global mask normalization.
NEEDLE_WEIGHT = 10.0


def gen_batch(rng, batch, length, task=None):
    """Batch of (tokens[B,L], mask[B,L]); mixed tasks when ``task=None``."""
    toks = np.zeros((batch, length), dtype=np.int32)
    mask = np.zeros((batch, length), dtype=np.float32)
    pool = [n for n, w in TASK_WEIGHTS.items() for _ in range(w)]
    for b in range(batch):
        name = task or pool[int(rng.integers(0, len(pool)))]
        toks[b], mask[b] = GENERATORS[name](rng, length)
    return toks, mask
