"""Layer 2: a small LLaMA-style decoder in JAX, with pluggable attention.

The attention implementation is selected per call:

  * ``mode="native"`` — exact attention (the paper's SDPA baseline),
  * ``mode="dma"``    — the Pallas Diagonal-Tiled Mixed-Precision kernel
                        (quantized Q/K, high-precision diagonal window).

Architecture: RMSNorm -> GQA attention with RoPE -> SwiGLU MLP, tied
embedding/unembedding. The model is deliberately small (it is trained at
artifact-build time on the synthetic long-context tasks in ``tasks.py``)
but uses the exact block structure of the paper's LLaMA-3 targets, so the
DMA kernel is exercised the same way.

Everything here runs ONLY at build time: ``aot.py`` lowers prefill /
decode / eval graphs to HLO text that the Rust runtime executes via PJRT.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dma_attention as dak
from .kernels import ref as kref
from . import tasks


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = tasks.VOCAB
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 32          # must be a multiple of 32 (MXFP block)
    d_ff: int = 256
    max_seq: int = 512
    rope_theta: float = 10000.0
    # DMA attention tiling (paper default config: 128/128 at bm=bn=64;
    # scaled to this model's shorter contexts).
    bm: int = 32
    bn: int = 32
    diag: int = 64
    sink: int = 32

    def as_dict(self):
        return asdict(self)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

PARAM_ORDER_NOTE = (
    "flatten_params() order: embed, then per layer "
    "[ln1, wq, wk, wv, wo, ln2, w1, w2, w3], then ln_f"
)


def init_params(rng, cfg: ModelConfig):
    """Initialize a parameter pytree (dict of dicts)."""
    def dense(key, fan_in, shape):
        return (jax.random.normal(key, shape, jnp.float32)
                * (1.0 / np.sqrt(fan_in)))

    keys = jax.random.split(rng, 2 + cfg.n_layers)
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,)),
        "layers": [],
    }
    dq = cfg.n_heads * cfg.d_head
    dkv = cfg.n_kv_heads * cfg.d_head
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[2 + li], 7)
        params["layers"].append({
            "ln1": jnp.ones((cfg.d_model,)),
            "wq": dense(k[0], cfg.d_model, (cfg.d_model, dq)),
            "wk": dense(k[1], cfg.d_model, (cfg.d_model, dkv)),
            "wv": dense(k[2], cfg.d_model, (cfg.d_model, dkv)),
            "wo": dense(k[3], dq, (dq, cfg.d_model)),
            "ln2": jnp.ones((cfg.d_model,)),
            "w1": dense(k[4], cfg.d_model, (cfg.d_model, cfg.d_ff)),
            "w2": dense(k[5], cfg.d_ff, (cfg.d_ff, cfg.d_model)),
            "w3": dense(k[6], cfg.d_model, (cfg.d_model, cfg.d_ff)),
        })
    return params


def flatten_params(params, cfg: ModelConfig):
    """Deterministic (name, array) list — the weights.bin layout contract
    shared with ``rust/src/model/weights.rs``."""
    out = [("embed", params["embed"])]
    for li in range(cfg.n_layers):
        lp = params["layers"][li]
        for name in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2", "w3"):
            out.append((f"layers.{li}.{name}", lp[name]))
    out.append(("ln_f", params["ln_f"]))
    return out


def unflatten_params(arrays, cfg: ModelConfig):
    """Inverse of :func:`flatten_params` from a flat list of arrays."""
    it = iter(arrays)
    params = {"embed": next(it), "layers": []}
    for _ in range(cfg.n_layers):
        lp = {}
        for name in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2", "w3"):
            lp[name] = next(it)
        params["layers"].append(lp)
    params["ln_f"] = next(it)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope_angles(cfg: ModelConfig, positions):
    """[T] -> cos/sin tables [T, d_head/2]."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., T, d_head]; rotate pairs (even, odd)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x1 * sin + x2 * cos
    return jnp.stack([out1, out2], axis=-1).reshape(x.shape)


def _repeat_kv(x, n_rep):
    """[H_kv, T, Dh] -> [H_kv * n_rep, T, Dh] (GQA broadcast)."""
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=0)


def _attention_heads(q, k, v, cfg: ModelConfig, mode):
    """q,k,v: [H, T, Dh] -> [H, T, Dh]; causal."""
    if mode == "native":
        return jax.vmap(
            lambda qq, kk, vv: kref.attention_ref(qq, kk, vv, causal=True)
        )(q, k, v)
    if mode == "dma":
        return dak.dma_attention_mha(
            q, k, v, bm=cfg.bm, bn=cfg.bn, diag=cfg.diag, sink=cfg.sink,
            causal=True,
        )
    raise ValueError(f"unknown attention mode {mode!r}")


def block(params, x, cfg: ModelConfig, mode, cos, sin):
    """One transformer block over [T, d_model]."""
    t = x.shape[0]
    h = rmsnorm(x, params["ln1"])
    q = (h @ params["wq"]).reshape(t, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ params["wk"]).reshape(t, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ params["wv"]).reshape(t, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    o = _attention_heads(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep), cfg, mode)
    x = x + o.transpose(1, 0, 2).reshape(t, -1) @ params["wo"]
    h = rmsnorm(x, params["ln2"])
    x = x + (jax.nn.silu(h @ params["w1"]) * (h @ params["w3"])) @ params["w2"]
    return x


def forward(params, tokens, cfg: ModelConfig, mode="native"):
    """tokens [T]int32 -> logits [T, vocab]. Single sequence, causal."""
    t = tokens.shape[0]
    x = params["embed"][tokens]
    cos, sin = rope_angles(cfg, jnp.arange(t))
    for lp in params["layers"]:
        x = block(lp, x, cfg, mode, cos, sin)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T


def forward_batch(params, tokens, cfg: ModelConfig, mode="native"):
    """tokens [B, T] -> logits [B, T, vocab]."""
    return jax.vmap(lambda tt: forward(params, tt, cfg, mode))(tokens)


# ---------------------------------------------------------------------------
# Prefill / decode with explicit KV cache (the serving interface)
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: ModelConfig, mode="native"):
    """tokens [T] -> (logits [T, vocab], k_cache, v_cache).

    Caches have shape [n_layers, n_kv_heads, T, d_head] and hold the
    *post-RoPE* keys, so decode never re-rotates history.
    """
    t = tokens.shape[0]
    x = params["embed"][tokens]
    cos, sin = rope_angles(cfg, jnp.arange(t))
    kc, vc = [], []
    for lp in params["layers"]:
        h = rmsnorm(x, lp["ln1"])
        q = (h @ lp["wq"]).reshape(t, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
        k = (h @ lp["wk"]).reshape(t, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
        v = (h @ lp["wv"]).reshape(t, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        kc.append(k)
        vc.append(v)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        o = _attention_heads(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep),
                             cfg, mode)
        x = x + o.transpose(1, 0, 2).reshape(t, -1) @ lp["wo"]
        hh = rmsnorm(x, lp["ln2"])
        x = x + (jax.nn.silu(hh @ lp["w1"]) * (hh @ lp["w3"])) @ lp["w2"]
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T
    return logits, jnp.stack(kc), jnp.stack(vc)


def decode_step(params, token, k_cache, v_cache, pos, cfg: ModelConfig):
    """One decode step for a single sequence.

    token   : int32 scalar — the token at position ``pos``.
    k_cache : [n_layers, n_kv_heads, C, d_head] (post-RoPE keys).
    pos     : int32 scalar — number of tokens already in the cache.

    Returns (logits [vocab], k_cache', v_cache'). Decode attends over the
    cache with a validity mask ``arange(C) <= pos``; full precision (the
    paper's kernel targets the quadratic prefill phase — decode is a
    bandwidth-bound GEMV where tile-level mixed precision degenerates to
    the diagonal window anyway).
    """
    c = k_cache.shape[2]
    x = params["embed"][token]
    cos, sin = rope_angles(cfg, pos[None].astype(jnp.float32))
    new_k, new_v = [], []
    for li, lp in enumerate(params["layers"]):
        h = rmsnorm(x, lp["ln1"])
        q = (h @ lp["wq"]).reshape(cfg.n_heads, 1, cfg.d_head)
        k = (h @ lp["wk"]).reshape(cfg.n_kv_heads, 1, cfg.d_head)
        v = (h @ lp["wv"]).reshape(cfg.n_kv_heads, 1, cfg.d_head)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        kc = jax.lax.dynamic_update_slice(k_cache[li], k, (0, pos, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[li], v, (0, pos, 0))
        new_k.append(kc)
        new_v.append(vc)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        kk = _repeat_kv(kc, n_rep)
        vv = _repeat_kv(vc, n_rep)
        s = jnp.einsum("hod,hcd->hoc", q, kk) / np.sqrt(cfg.d_head)
        valid = (jnp.arange(c) <= pos)[None, None, :]
        s = jnp.where(valid, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hoc,hcd->hod", p, vv).reshape(1, -1)
        x = x + (o @ lp["wo"])[0]
        hh = rmsnorm(x, lp["ln2"])
        x = x + (jax.nn.silu(hh @ lp["w1"]) * (hh @ lp["w3"])) @ lp["w2"]
    x = rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T, jnp.stack(new_k), jnp.stack(new_v)


def decode_step_batch(params, tokens, k_cache, v_cache, pos, cfg: ModelConfig):
    """Batched decode: tokens [B], caches [n_layers, B, H_kv, C, d_head],
    pos [B] -> (logits [B, vocab], caches')."""
    def one(tok, kc, vc, p):
        return decode_step(params, tok, kc, vc, p, cfg)

    logits, kc, vc = jax.vmap(one, in_axes=(0, 1, 1, 0), out_axes=(0, 1, 1))(
        tokens, k_cache, v_cache, pos)
    return logits, kc, vc


# ---------------------------------------------------------------------------
# Build-time training (Adam, hand-rolled — optax is not vendored)
# ---------------------------------------------------------------------------

def loss_fn(params, tokens, mask, cfg: ModelConfig):
    """Masked next-token cross-entropy over a [B, T] batch.

    """
    logits = forward_batch(params, tokens, cfg, mode="native")
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, :-1]
    # Global weighted-mask normalization: abundant copy/induction tokens
    # drive circuit formation while NEEDLE_WEIGHT (see tasks.py) keeps
    # the sparse needle answers from being drowned out.
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g,
                               state["v"], grads)
    tf = t.astype(jnp.float32)
    def upd(p, mm, vv):
        mh = mm / (1 - b1 ** tf)
        vh = vv / (1 - b2 ** tf)
        return p - lr * mh / (jnp.sqrt(vh) + eps)
    return (jax.tree_util.tree_map(upd, params, m, v),
            {"m": m, "v": v, "t": t})


def train(cfg: ModelConfig, steps=400, batch=16, length=256, seed=0,
          lr=3e-3, lr_min=3e-4, warmup=50, log_every=50, verbose=True):
    """Train the model on the synthetic task mixture; returns params.

    Linear warmup then cosine decay from ``lr`` to ``lr_min``.
    """
    rng = np.random.default_rng(seed)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, mask, lr_t):
        loss, grads = jax.value_and_grad(loss_fn)(params, toks, mask, cfg)
        params, opt = adam_update(params, grads, opt, lr=lr_t)
        return params, opt, loss

    def lr_at(step):
        if step < warmup:
            return lr * (step + 1) / warmup
        frac = (step - warmup) / max(1, steps - warmup)
        return lr_min + 0.5 * (lr - lr_min) * (1 + np.cos(np.pi * frac))

    history = []
    for step in range(steps):
        toks, mask = tasks.gen_batch(rng, batch, length)
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks),
                                    jnp.asarray(mask),
                                    jnp.float32(lr_at(step)))
        history.append(float(loss))
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"  train step {step:4d}  loss {float(loss):.4f}")
    return params, history


# ---------------------------------------------------------------------------
# Evaluation (Table 3 proxy)
# ---------------------------------------------------------------------------

def eval_accuracy(params, cfg: ModelConfig, mode, task, length, n=32, seed=1):
    """Masked-position greedy accuracy for one task at one length."""
    rng = np.random.default_rng(seed)
    toks, mask = tasks.gen_batch(rng, n, length, task=task)
    logits = forward_batch(params, jnp.asarray(toks), cfg, mode=mode)
    pred = jnp.argmax(logits[:, :-1], axis=-1)
    tgt = jnp.asarray(toks)[:, 1:]
    m = jnp.asarray(mask)[:, :-1]
    correct = jnp.sum((pred == tgt) * m)
    return float(correct / jnp.maximum(jnp.sum(m), 1.0))
