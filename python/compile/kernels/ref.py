"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is deliberately naive and materializes full matrices; the
Pallas kernels (tiled, online-softmax, fused quantization) must match these
outputs. The oracles are also the ground truth for the paper's error
metrics (Table 2 / 5 / 8 reproductions on the Rust side use the same
semantics, cross-checked through golden vectors).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import mxfp


# ---------------------------------------------------------------------------
# Exact attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, causal=True):
    """Exact softmax attention. q:[Lq,D] k,v:[Lk,D] -> [Lq,D]."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        lq, lk = q.shape[0], k.shape[0]
        # Standard decoder alignment: query i attends keys j <= i + (Lk - Lq).
        mask = jnp.arange(lk)[None, :] > (jnp.arange(lq)[:, None] + (lk - lq))
        s = jnp.where(mask, -jnp.inf, s)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def attention_scores_ref(q, k, causal=True):
    """Post-softmax attention matrix P (for similarity metrics)."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        lq, lk = q.shape[0], k.shape[0]
        mask = jnp.arange(lk)[None, :] > (jnp.arange(lq)[:, None] + (lk - lq))
        s = jnp.where(mask, -jnp.inf, s)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    return p / jnp.sum(p, axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# Dual-quantization reference (Algorithm 2 at value level)
# ---------------------------------------------------------------------------

def dual_quant_ref(x, is_query):
    """Reference for the fused dual-MXFP quantization kernel.

    Returns ``(x_low, x_high, sq)`` where

      * ``x_low``  — NVFP4 dequantized copy (E2M1 + E4M3 block-16 scales),
      * ``x_high`` — MXFP8  dequantized copy (E4M3 + E8M0 block-32 scales),
      * ``sq``     — the per-token quantization scale [rows, 1],

    all including the softmax pre-scale ``log2(e)/sqrt(D)`` when
    ``is_query`` (Alg. 2 Step 1). Both copies satisfy
    ``x_* ~= x * softmax_scale`` up to format error, so the attention
    kernel may consume them directly with a base-2 softmax.
    """
    x = jnp.asarray(x, jnp.float32)
    d = x.shape[-1]
    if is_query:
        x = x * (mxfp.LOG2_E / jnp.sqrt(jnp.float32(d)))
    # Step 2: per-token scale into NVFP4's two-level representable range.
    sq = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / (mxfp.E4M3_MAX * mxfp.E2M1_MAX)
    sq = jnp.maximum(sq, 1e-30)
    xs = x / sq

    # Steps 3-5: NVFP4 low-precision copy.
    xb = xs.reshape(*xs.shape[:-1], d // mxfp.NVFP4_BLOCK, mxfp.NVFP4_BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    s4, _ = mxfp.nvfp4_shared_scale(amax)
    q4 = mxfp.quantize_e2m1(jnp.clip(xb / s4, -mxfp.E2M1_MAX, mxfp.E2M1_MAX))
    x_low = (q4 * s4).reshape(x.shape) * sq

    # Steps 6-7: MXFP8 high-precision copy.
    xb8 = xs.reshape(*xs.shape[:-1], d // mxfp.MXFP_BLOCK, mxfp.MXFP_BLOCK)
    amax8 = jnp.max(jnp.abs(xb8), axis=-1, keepdims=True)
    s8, _ = mxfp.e8m0_shared_scale(amax8, mxfp.E4M3_EMAX)
    q8 = mxfp.quantize_e4m3(jnp.clip(xb8 / s8, -mxfp.E4M3_MAX, mxfp.E4M3_MAX))
    x_high = (q8 * s8).reshape(x.shape) * sq

    return x_low, x_high, sq


# ---------------------------------------------------------------------------
# DMA attention reference (Algorithm 1 at matrix level)
# ---------------------------------------------------------------------------

def dma_attention_ref(q, k, v, diag=128, sink=0, causal=True):
    """Diagonal-tiled mixed-precision attention, computed naively.

    Logit-level mixture: positions within the diagonal window of width
    ``diag`` (and the first ``sink`` key positions) use the MXFP8
    high-precision copies of Q/K; everything else uses the NVFP4
    low-precision copies. Softmax is then exact. This is precisely what
    Algorithm 1 computes tile-wise with OnlineSoftmax, with tile size 1.

    The Pallas kernel makes the same decision at *tile* granularity; pass
    ``diag``/``sink`` as multiples of the kernel tile sizes to compare, and
    use :func:`dma_attention_tiled_ref` for the exact tile-level oracle.
    """
    return dma_attention_tiled_ref(q, k, v, diag=diag, sink=sink, causal=causal,
                                   bm=1, bn=1)


def dma_attention_tiled_ref(q, k, v, diag=128, sink=0, causal=True,
                            bm=64, bn=64):
    """Tile-level oracle matching the kernel's per-tile precision choice.

    A KV tile (row block i of size ``bm``, col block j of size ``bn``) is
    high-precision iff it intersects the diagonal band of width ``diag``
    ending at the causal frontier of query tile i, or the first ``sink``
    key positions. With ``bm = bn = 1`` this degrades to the token-level
    mixture of :func:`dma_attention_ref`.
    """
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    lq, d = q.shape
    lk = k.shape[0]

    ql, qh, _ = dual_quant_ref(q, is_query=True)
    kl, kh, _ = dual_quant_ref(k, is_query=False)

    # Logits in base-2 domain (softmax scale already folded into Q).
    s_low = ql @ kl.T
    s_high = qh @ kh.T

    qi = jnp.arange(lq)[:, None]
    kj = jnp.arange(lk)[None, :]
    off = lk - lq  # causal frontier offset for rectangular Q/K
    ti = qi // bm  # query tile index of each row
    tj = kj // bn  # key tile index of each column
    # Frontier position of the *query tile* (its last row), mirroring the
    # kernel: the high window covers key tiles intersecting
    # (frontier - diag, frontier].
    tile_frontier = ti * bm + (bm - 1) + off
    if diag > 0:
        win_start = tile_frontier - (diag - 1)
        hi_diag = (tj * bn + (bn - 1) >= win_start) & (tj * bn <= tile_frontier)
    else:
        hi_diag = jnp.zeros(s_low.shape, dtype=bool)
    hi_sink = (tj * bn) < sink if sink > 0 else jnp.zeros_like(hi_diag)
    if not causal and diag > 0:
        # Non-causal: window of total width `diag` centred on the diagonal.
        centre = qi + off
        half = diag // 2
        lo_edge = centre - half
        hi_edge = centre + half
        t_lo = (tj * bn + (bn - 1) >= lo_edge) & (tj * bn <= hi_edge)
        hi_diag = t_lo
    high = hi_diag | hi_sink

    s = jnp.where(high, s_high, s_low)
    if causal:
        s = jnp.where(kj > qi + off, -jnp.inf, s)
    # Base-2 softmax (the kernel computes exp2; equivalent numerics).
    p = jnp.exp2(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def high_fraction(lq, lk, diag, sink, bm, bn, causal=True):
    """Fraction of the (causally valid) attention area computed in high
    precision — the "Bithigh%" column of Table 5."""
    import numpy as np

    qi = np.arange(lq)[:, None]
    kj = np.arange(lk)[None, :]
    off = lk - lq
    ti = qi // bm
    tj = kj // bn
    tile_frontier = ti * bm + (bm - 1) + off
    win_start = tile_frontier - (diag - 1)
    hi = np.zeros((lq, lk), dtype=bool)
    if diag > 0:
        hi |= (tj * bn + (bn - 1) >= win_start) & (tj * bn <= tile_frontier)
    if sink > 0:
        hi |= (tj * bn) < sink
    valid = kj <= qi + off if causal else np.ones_like(hi)
    hi &= valid
    return float(hi.sum()) / float(valid.sum())
