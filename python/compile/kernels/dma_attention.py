"""Diagonal-Tiled Mixed-Precision Attention as a Pallas kernel (Alg. 1).

The kernel consumes the *bit-level* outputs of the fused dual-quantization
kernel (``quant_fused.dual_quant``): packed E2M1 nibbles + E4M3 block
scales for the low-precision path, E4M3 codes + E8M0 block exponents for
the high-precision path, and the per-token scale ``S_q``. Decoding happens
in VMEM right before the tile matmul — nothing is dequantized in HBM.

Tiling follows the paper exactly: one grid step per query tile ``i``
(size ``bm``); inside, the KV axis is walked in ``bn``-sized tiles in
three phases —

  Phase 0 (sink)  : the first ``sink`` key tokens, high precision,
  Phase 1 (low)   : everything before the diagonal window, low precision,
  Phase 2 (diag)  : the window of ``diag`` tokens ending at the causal
                    frontier of tile ``i``, high precision + causal mask,

all stitched together with base-2 OnlineSoftmax (the ``log2 e`` factor is
pre-folded into Q by the quantization kernel, so ``exp2`` replaces
``exp``). For non-causal attention the window straddles the diagonal
(``diag/2`` on each side) and Phase 1 covers both the lower and upper
triangles, mirroring the paper's Sec. 5.2 "Compatibility with Non-Causal
Attention".

Hardware adaptation (see DESIGN.md §5): the TPU MXU has no FP4/FP8 MMA
path, so the matmuls run in f32 over bit-exactly decoded operands; the
format-level speedup is modelled in ``rust/src/perfmodel``. Pallas is used
with ``interpret=True`` — CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import mxfp
from . import quant_fused

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# In-kernel tile dequantization
# ---------------------------------------------------------------------------

def _decode_low_tile(packed, s4_codes, sq):
    """[rows, d/2]u8 + [rows, d/16]u8 + [rows, 1]f32 -> [rows, d]f32."""
    codes = mxfp.unpack_fp4(packed)
    vals = mxfp.decode_e2m1(codes)
    rows, d = vals.shape
    vb = vals.reshape(rows, d // mxfp.NVFP4_BLOCK, mxfp.NVFP4_BLOCK)
    s4 = mxfp.decode_e4m3(s4_codes)[..., None]
    return (vb * s4).reshape(rows, d) * sq


def _decode_high_tile(fp8_codes, s8_codes, sq):
    """[rows, d]u8 + [rows, d/32]u8 + [rows, 1]f32 -> [rows, d]f32."""
    vals = mxfp.decode_e4m3(fp8_codes)
    rows, d = vals.shape
    vb = vals.reshape(rows, d // mxfp.MXFP_BLOCK, mxfp.MXFP_BLOCK)
    s8 = mxfp.pow2i(s8_codes.astype(jnp.float32) - 127.0)[..., None]
    return (vb * s8).reshape(rows, d) * sq


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------

def _dma_kernel(
    qpk_ref, qs4_ref, qf8_ref, qs8_ref, qsq_ref,
    kpk_ref, ks4_ref, kf8_ref, ks8_ref, ksq_ref,
    v_ref, o_ref,
    *, bm, bn, d, lq, lk, diag, sink, causal,
):
    i = pl.program_id(0)
    off = lk - lq  # causal frontier offset for rectangular Q/K
    nk = lk // bn

    # Decode both precision copies of this query tile once.
    q_sq = qsq_ref[...]
    q_low = _decode_low_tile(qpk_ref[...], qs4_ref[...], q_sq)
    q_high = _decode_high_tile(qf8_ref[...], qs8_ref[...], q_sq)

    row_ids = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    col_base = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)

    def make_tile_step(use_high, apply_mask):
        def step(j, carry):
            m, l, acc = carry
            ks = pl.ds(j * bn, bn)
            k_sq = ksq_ref[ks, :]
            if use_high:
                k_tile = _decode_high_tile(kf8_ref[ks, :], ks8_ref[ks, :], k_sq)
                q_tile = q_high
            else:
                k_tile = _decode_low_tile(kpk_ref[ks, :], ks4_ref[ks, :], k_sq)
                q_tile = q_low
            # Base-2 logits: softmax scale already folded into Q.
            s = jax.lax.dot_general(
                q_tile, k_tile,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            if apply_mask:
                cols = j * bn + col_base
                valid = cols <= row_ids + off
                s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=1))
            alpha = jnp.exp2(m - m_new)
            p = jnp.exp2(s - m_new[:, None])
            l_new = l * alpha + jnp.sum(p, axis=1)
            v_tile = v_ref[ks, :]
            acc_new = acc * alpha[:, None] + jax.lax.dot_general(
                p, v_tile, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            return m_new, l_new, acc_new

        return step

    m0 = jnp.full((bm,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bm,), jnp.float32)
    acc0 = jnp.zeros((bm, d), jnp.float32)
    carry = (m0, l0, acc0)

    frontier = i * bm + (bm - 1) + off
    if causal:
        j_end = jnp.minimum(frontier // bn + 1, nk)
        # First high tile of the diagonal window (Phase 2 start).
        j_hi_start = (frontier - diag + 1) // bn if diag > 0 else j_end
    else:
        j_end = jnp.int32(nk)
        half = diag // 2
        j_hi_start = (frontier - half) // bn if diag > 0 else j_end
        j_hi_end = jnp.minimum((frontier + half) // bn + 1, nk) if diag > 0 else j_end
    n_sink = -(-sink // bn) if sink > 0 else 0

    if causal:
        # Order matters: cap the sink tile count at the causal end first,
        # so clip() below never sees min > max (which would push
        # j_hi_start past j_end and walk tiles outside the KV range).
        n_sink_eff = jnp.minimum(jnp.int32(n_sink), j_end)
        j_hi_start = jnp.clip(j_hi_start, n_sink_eff, j_end)
        # Phase 0: attention-sink tiles, high precision.
        carry = jax.lax.fori_loop(
            0, n_sink_eff, make_tile_step(True, True), carry)
        # Phase 1: low-precision tiles up to the diagonal window.
        carry = jax.lax.fori_loop(
            n_sink_eff, j_hi_start, make_tile_step(False, True), carry)
        # Phase 2: high-precision tiles inside the window (+ causal mask).
        carry = jax.lax.fori_loop(
            j_hi_start, j_end, make_tile_step(True, True), carry)
    else:
        n_sink_cap = jnp.minimum(jnp.int32(n_sink), j_end)
        j_hi_start = jnp.clip(j_hi_start, n_sink_cap, j_end)
        j_hi_end = jnp.clip(j_hi_end, j_hi_start, j_end)
        n_sink_eff = jnp.minimum(n_sink_cap, j_hi_start)
        carry = jax.lax.fori_loop(
            0, n_sink_eff, make_tile_step(True, False), carry)
        # Phase 1a: lower-triangle low tiles.
        carry = jax.lax.fori_loop(
            n_sink_eff, j_hi_start, make_tile_step(False, False), carry)
        # Phase 2: the diagonal window, high precision.
        carry = jax.lax.fori_loop(
            j_hi_start, j_hi_end, make_tile_step(True, False), carry)
        # Phase 1b: upper-triangle low tiles.
        carry = jax.lax.fori_loop(
            j_hi_end, j_end, make_tile_step(False, False), carry)

    m, l, acc = carry
    o_ref[...] = acc / l[:, None]


# ---------------------------------------------------------------------------
# Host-side wrappers
# ---------------------------------------------------------------------------

def dma_attention_quantized(
    q_quant, k_quant, v, *, bm=64, bn=64, diag=128, sink=0, causal=True,
    interpret=True,
):
    """Run DMA attention on pre-quantized operands.

    ``q_quant``/``k_quant`` are the 5-tuples returned by
    ``quant_fused.dual_quant`` (with ``is_query=True`` for Q). ``v`` is
    [Lk, D] float32. Returns [Lq, D] float32.
    """
    qpk, qs4, qf8, qs8, qsq = q_quant
    kpk, ks4, kf8, ks8, ksq = k_quant
    lq, d = qf8.shape
    lk = kf8.shape[0]
    assert lq % bm == 0 and lk % bn == 0, (lq, bm, lk, bn)

    kernel = functools.partial(
        _dma_kernel, bm=bm, bn=bn, d=d, lq=lq, lk=lk,
        diag=diag, sink=sink, causal=causal,
    )
    grid = (lq // bm,)
    qspec = [
        pl.BlockSpec((bm, d // 2), lambda i: (i, 0)),
        pl.BlockSpec((bm, d // mxfp.NVFP4_BLOCK), lambda i: (i, 0)),
        pl.BlockSpec((bm, d), lambda i: (i, 0)),
        pl.BlockSpec((bm, d // mxfp.MXFP_BLOCK), lambda i: (i, 0)),
        pl.BlockSpec((bm, 1), lambda i: (i, 0)),
    ]
    kspec = [
        pl.BlockSpec((lk, d // 2), lambda i: (0, 0)),
        pl.BlockSpec((lk, d // mxfp.NVFP4_BLOCK), lambda i: (0, 0)),
        pl.BlockSpec((lk, d), lambda i: (0, 0)),
        pl.BlockSpec((lk, d // mxfp.MXFP_BLOCK), lambda i: (0, 0)),
        pl.BlockSpec((lk, 1), lambda i: (0, 0)),
    ]
    vspec = [pl.BlockSpec((lk, d), lambda i: (0, 0))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=qspec + kspec + vspec,
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lq, d), jnp.float32),
        interpret=interpret,
    )(qpk, qs4, qf8, qs8, qsq, kpk, ks4, kf8, ks8, ksq, v)


def dma_attention(q, k, v, *, bm=64, bn=64, diag=128, sink=0, causal=True,
                  interpret=True):
    """Full DMA pipeline on float inputs: fused dual-quant, then the
    mixed-precision attention kernel. q:[Lq,D], k,v:[Lk,D] -> [Lq,D]."""
    q_quant = quant_fused.dual_quant(q, is_query=True, interpret=interpret)
    k_quant = quant_fused.dual_quant(k, is_query=False, interpret=interpret)
    return dma_attention_quantized(
        q_quant, k_quant, v, bm=bm, bn=bn, diag=diag, sink=sink,
        causal=causal, interpret=interpret,
    )


def dma_attention_mha(q, k, v, **kw):
    """Multi-head wrapper: q,k,v:[H, L, D] -> [H, Lq, D] (vmap over heads)."""
    return jax.vmap(lambda qq, kk, vv: dma_attention(qq, kk, vv, **kw))(q, k, v)


# ---------------------------------------------------------------------------
# Tile-level oracle on the kernel's own quantized operands (used by tests:
# isolates the tiling/online-softmax logic from quantization tie-breaks).
# ---------------------------------------------------------------------------

def dma_oracle_from_quants(q_quant, k_quant, v, *, bm=64, bn=64, diag=128,
                           sink=0, causal=True):
    qpk, qs4, qf8, qs8, qsq = q_quant
    kpk, ks4, kf8, ks8, ksq = k_quant
    ql = quant_fused.dequant_nvfp4(qpk, qs4, qsq)
    qh = quant_fused.dequant_mxfp8(qf8, qs8, qsq)
    kl = quant_fused.dequant_nvfp4(kpk, ks4, ksq)
    kh = quant_fused.dequant_mxfp8(kf8, ks8, ksq)
    lq, _ = ql.shape
    lk = kl.shape[0]
    off = lk - lq

    s_low = ql @ kl.T
    s_high = qh @ kh.T

    qi = jnp.arange(lq)[:, None]
    kj = jnp.arange(lk)[None, :]
    ti, tj = qi // bm, kj // bn
    frontier = ti * bm + (bm - 1) + off
    if causal:
        if diag > 0:
            win_start = frontier - (diag - 1)
            hi = (tj * bn + (bn - 1) >= win_start) & (tj * bn <= frontier)
        else:
            hi = jnp.zeros(s_low.shape, bool)
    else:
        if diag > 0:
            half = diag // 2
            j_hs = (frontier - half) // bn
            j_he = (frontier + half) // bn
            hi = (tj >= j_hs) & (tj <= j_he)
        else:
            hi = jnp.zeros(s_low.shape, bool)
    if sink > 0:
        n_sink = -(-sink // bn)
        hi = hi | (tj < n_sink)
    s = jnp.where(hi, s_high, s_low)
    if causal:
        s = jnp.where(kj > qi + off, NEG_INF, s)
    p = jnp.exp2(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v
