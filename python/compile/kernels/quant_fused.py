"""Fused dual-MXFP quantization as a Pallas kernel (paper Algorithm 2).

One grid pass over row tiles of an FP32/FP16 input produces, without any
intermediate HBM round-trips:

  * the NVFP4 low-precision copy — E2M1 codes packed two-per-byte plus the
    per-16-element E4M3 shared scales,
  * the MXFP8 high-precision copy — E4M3 codes plus the per-32-element
    E8M0 shared exponents,
  * the per-token quantization scale ``S_q`` (Alg. 2 Step 2),

with the softmax factor ``log2(e)/sqrt(D)`` pre-folded for query tensors
(Step 1) so the attention kernel can run its softmax in base-2 arithmetic.

This is the TPU/Pallas analogue of the paper's fused Triton kernel: the
whole of Alg. 2 (quantization scale, shared scales, E2M1 encode, nibble
packing, E8M0 conversion, both precisions) happens on one VMEM-resident
tile per grid step. The unfused baseline it is ablated against (Tables 6
and 7) lives in ``rust/src/mxfp/unfused.rs``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import mxfp


def _dual_quant_kernel(x_ref, packed_ref, s4_ref, fp8_ref, s8_ref, sq_ref,
                       *, is_query):
    """Pallas body: Algorithm 2 over one [bt, d] row tile."""
    x = x_ref[...].astype(jnp.float32)
    d = x.shape[-1]

    # Step 1: pre-fold the base-2 softmax scale into Q.
    if is_query:
        x = x * (mxfp.LOG2_E / jnp.sqrt(jnp.float32(d)))

    # Step 2: per-token quantization scale into NVFP4's two-level range.
    sq = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / (
        mxfp.E4M3_MAX * mxfp.E2M1_MAX
    )
    sq = jnp.maximum(sq, 1e-30)
    xs = x / sq
    sq_ref[...] = sq

    # Steps 3-5: NVFP4 branch — per-16 E4M3 scale, E2M1 encode, pack.
    xb = xs.reshape(xs.shape[0], d // mxfp.NVFP4_BLOCK, mxfp.NVFP4_BLOCK)
    amax4 = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    s4, s4_code = mxfp.nvfp4_shared_scale(amax4)
    clamped = jnp.clip(xb / s4, -mxfp.E2M1_MAX, mxfp.E2M1_MAX)
    codes = mxfp.encode_e2m1(clamped).reshape(xs.shape[0], d)
    packed_ref[...] = mxfp.pack_fp4(codes)
    s4_ref[...] = s4_code[..., 0]

    # Steps 6-7: MXFP8 branch — per-32 E8M0 exponent, E4M3 encode.
    xb8 = xs.reshape(xs.shape[0], d // mxfp.MXFP_BLOCK, mxfp.MXFP_BLOCK)
    amax8 = jnp.max(jnp.abs(xb8), axis=-1, keepdims=True)
    s8, s8_code = mxfp.e8m0_shared_scale(amax8, mxfp.E4M3_EMAX)
    x8 = jnp.clip(xb8 / s8, -mxfp.E4M3_MAX, mxfp.E4M3_MAX)
    fp8_ref[...] = mxfp.encode_e4m3(x8).reshape(xs.shape[0], d)
    s8_ref[...] = s8_code[..., 0]


def dual_quant(x, is_query, block_rows=128, interpret=True):
    """Run the fused dual-quantization kernel over ``x``:[L, D].

    Returns ``(packed_fp4, s4_codes, fp8_codes, s8_codes, sq)`` with shapes
    ``[L, D/2]u8, [L, D/16]u8, [L, D]u8, [L, D/32]u8, [L, 1]f32``.
    """
    l, d = x.shape
    assert d % mxfp.MXFP_BLOCK == 0, f"D={d} must be a multiple of 32"
    # Largest row tile <= block_rows that divides L (trace-time search).
    bt = next(t for t in range(min(block_rows, l), 0, -1) if l % t == 0)
    grid = (l // bt,)

    kernel = functools.partial(_dual_quant_kernel, is_query=is_query)
    out_shapes = (
        jax.ShapeDtypeStruct((l, d // 2), jnp.uint8),
        jax.ShapeDtypeStruct((l, d // mxfp.NVFP4_BLOCK), jnp.uint8),
        jax.ShapeDtypeStruct((l, d), jnp.uint8),
        jax.ShapeDtypeStruct((l, d // mxfp.MXFP_BLOCK), jnp.uint8),
        jax.ShapeDtypeStruct((l, 1), jnp.float32),
    )
    in_specs = [pl.BlockSpec((bt, d), lambda i: (i, 0))]
    out_specs = (
        pl.BlockSpec((bt, d // 2), lambda i: (i, 0)),
        pl.BlockSpec((bt, d // mxfp.NVFP4_BLOCK), lambda i: (i, 0)),
        pl.BlockSpec((bt, d), lambda i: (i, 0)),
        pl.BlockSpec((bt, d // mxfp.MXFP_BLOCK), lambda i: (i, 0)),
        pl.BlockSpec((bt, 1), lambda i: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# Dequantization helpers (consumed by the attention kernel and by tests)
# ---------------------------------------------------------------------------

def dequant_nvfp4(packed, s4_codes, sq):
    """Reconstruct the low-precision copy: [L, D] float32."""
    codes = mxfp.unpack_fp4(packed)
    vals = mxfp.decode_e2m1(codes)
    l, d = vals.shape
    vb = vals.reshape(l, d // mxfp.NVFP4_BLOCK, mxfp.NVFP4_BLOCK)
    s4 = mxfp.decode_e4m3(s4_codes)[..., None]
    return (vb * s4).reshape(l, d) * sq


def dequant_mxfp8(fp8_codes, s8_codes, sq):
    """Reconstruct the high-precision copy: [L, D] float32."""
    vals = mxfp.decode_e4m3(fp8_codes)
    l, d = vals.shape
    vb = vals.reshape(l, d // mxfp.MXFP_BLOCK, mxfp.MXFP_BLOCK)
    s8 = mxfp.pow2i(s8_codes.astype(jnp.float32) - 127.0)[..., None]
    return (vb * s8).reshape(l, d) * sq
