"""MXFP format primitives (jnp, traceable inside Pallas kernels).

Implements the microscaling floating-point (MXFP) format zoo of the paper
(Table 1) as branch-free jax-numpy code so the same functions can be used

  * inside Pallas kernels (interpret=True on CPU),
  * in the pure-jnp reference oracle (``ref.py``), and
  * to generate cross-language golden vectors for the Rust mirror
    (``rust/src/mxfp``).

Formats
-------
=======  =====  ==========  ===========
Name     Block  Element     Shared scale
=======  =====  ==========  ===========
MXFP8    32     E4M3/E5M2   E8M0 (8 bit)
MXFP4    32     E2M1        E8M0 (8 bit)
NVFP4    16     E2M1        E4M3 (8 bit)
=======  =====  ==========  ===========

Encoding semantics follow Algorithm 2/3 of the paper. One deliberate
deviation, documented in DESIGN.md: Algorithm 3 states the subnormal
mantissa threshold as ``X_norm > 0.25`` while calling 0.25 "the midpoint
of 0 and 0.5"; in the normalized domain (``X_norm = |x| / 2^{E-1}``) that
midpoint is 0.5, so we use ``X_norm > 0.5`` (equivalently ``|x| > 0.25``),
which is the stated intent. Like the paper's algorithm, values never round
*up* across an exponent boundary (e.g. 1.75 -> 1.5, not 2.0); this is the
published kernel's behaviour and we reproduce it faithfully.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Element format constants (paper Sec. 5.3)
# ---------------------------------------------------------------------------

# E2M1 (FP4): 1 sign, 2 exponent, 1 mantissa. Representable magnitudes:
# 0, 0.5, 1, 1.5, 2, 3, 4, 6.
E2M1_MAX = 6.0
# Largest-normal exponent of E2M1: 6 = 1.5 * 2^2  ->  e_max = 2.
E2M1_EMAX = 2

# E4M3 (FN variant, as on Blackwell/OCP): bias 7, max normal 448
# (S.1111.110 = 1.75 * 2^8); S.1111.111 is NaN, never emitted.
E4M3_MAX = 448.0
E4M3_EMAX = 8  # paper: "In E4M3, e_max = 8"

# E5M2 (IEEE-like): bias 15, max normal 57344 = 1.75 * 2^15.
E5M2_MAX = 57344.0
E5M2_EMAX = 15

# Block sizes (Table 1).
NVFP4_BLOCK = 16
MXFP_BLOCK = 32

# Softmax scale folded into Q before quantization (Alg. 2 Step 1). The
# kernel computes softmax in base-2 arithmetic, hence the log2(e) factor.
LOG2_E = 1.4426950408889634

_EPS = 1e-30


def pow2i(e):
    """Exact 2^e for integer-valued exponents in [-126, 127].

    ``jnp.exp2`` lowers to an approximation on CPU XLA (exp2(13) can come
    back as 8192.0039!), which corrupts power-of-two scale arithmetic.
    Construct the float bit pattern directly instead. Exponents below
    -126 clamp to 2^-126 (denormal E8M0 corner; documented deviation).
    """
    ei = jnp.clip(jnp.asarray(e), -126, 127).astype(jnp.int32)
    return jax.lax.bitcast_convert_type((ei + 127) << 23, jnp.float32)


def _floor_log2(a):
    """Exact floor(log2(a)) for positive floats.

    ``jnp.log2`` can return 2.9999997 for an exact 8.0; a plain floor then
    misclassifies the octave and the derived mantissa overflows its bit
    budget. Correct the estimate by one step in either direction.
    """
    e = jnp.floor(jnp.log2(jnp.maximum(a, _EPS)))
    e = jnp.where(a >= pow2i(e + 1.0), e + 1.0, e)
    e = jnp.where(a < pow2i(e), e - 1.0, e)
    return e


# ---------------------------------------------------------------------------
# E2M1 encode/decode (Algorithm 3)
# ---------------------------------------------------------------------------

def encode_e2m1(x):
    """Encode a clamped tensor (|x| <= 6) into 4-bit E2M1 codes (uint8).

    Faithful, branch-free implementation of Algorithm 3:
      Step 4.1  sign bit
      Step 4.2  2-bit exponent by thresholding |x| against {1, 2, 4}
      Step 4.3  1-bit mantissa against the normalized midpoint (strict >,
                so ties round to even mantissa M=0)
      Step 4.4  assemble (S << 3) | (E << 1) | M
    """
    x = jnp.asarray(x, jnp.float32)
    s = (x < 0).astype(jnp.uint8)
    a = jnp.abs(x)
    e = (
        (a >= 1.0).astype(jnp.uint8)
        + (a >= 2.0).astype(jnp.uint8)
        + (a >= 4.0).astype(jnp.uint8)
    )
    # X_norm = |x| / 2^(E - bias), bias = 1.
    norm = a * pow2i(1.0 - e.astype(jnp.float32))
    m_sub = (norm > 0.5).astype(jnp.uint8)   # E == 0 (see module docstring)
    m_norm = (norm > 1.25).astype(jnp.uint8)  # E != 0: midpoint of {1, 1.5}
    m = jnp.where(e == 0, m_sub, m_norm)
    return ((s << 3) | (e << 1) | m).astype(jnp.uint8)


def decode_e2m1(code):
    """Decode 4-bit E2M1 codes (uint8, low nibble) back to float32."""
    code = jnp.asarray(code, jnp.uint8)
    s = ((code >> 3) & 1).astype(jnp.float32)
    e = ((code >> 1) & 3).astype(jnp.float32)
    m = (code & 1).astype(jnp.float32)
    sub = 0.5 * m                                   # E == 0: {0, 0.5}
    norm = pow2i(e - 1.0) * (1.0 + 0.5 * m)       # E != 0
    mag = jnp.where(e == 0, sub, norm)
    return jnp.where(s == 1, -mag, mag)


def quantize_e2m1(x):
    """Value-level E2M1 fake-quant: clamp, encode, decode."""
    x = jnp.clip(x, -E2M1_MAX, E2M1_MAX)
    return decode_e2m1(encode_e2m1(x))


# ---------------------------------------------------------------------------
# FP4 nibble packing (Algorithm 2, Step 5)
# ---------------------------------------------------------------------------

def pack_fp4(codes):
    """Pack two 4-bit codes into one uint8 along the last dim.

    The higher index goes to the most significant nibble (paper Step 5).
    The last dimension must be even.
    """
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return ((hi << 4) | lo).astype(jnp.uint8)


def unpack_fp4(packed):
    """Inverse of :func:`pack_fp4`: uint8 -> two interleaved 4-bit codes."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# E4M3 / E5M2 encode/decode
# ---------------------------------------------------------------------------

def _fp8_quant(x, emin, emax, mant_bits, max_val):
    """Round-to-nearest-even onto an FP8 grid, value level."""
    a = jnp.abs(jnp.asarray(x, jnp.float32))
    a = jnp.minimum(a, max_val)
    e = jnp.clip(_floor_log2(a), emin, emax)
    step = pow2i(e - mant_bits)
    q = jnp.minimum(jnp.round(a / step) * step, max_val)
    return jnp.sign(x) * q


def quantize_e4m3(x):
    """Value-level E4M3 fake-quant (RTN-even, clamp to +/-448)."""
    return _fp8_quant(x, emin=-6, emax=E4M3_EMAX, mant_bits=3, max_val=E4M3_MAX)


def quantize_e5m2(x):
    """Value-level E5M2 fake-quant (RTN-even, clamp to +/-57344)."""
    return _fp8_quant(x, emin=-14, emax=E5M2_EMAX, mant_bits=2, max_val=E5M2_MAX)


def encode_e4m3(x):
    """Encode float32 into E4M3 bit codes (uint8). Never emits NaN codes."""
    q = quantize_e4m3(x)
    s = (q < 0).astype(jnp.uint8)
    a = jnp.abs(q)
    e = jnp.clip(_floor_log2(a), -6, 8)
    is_sub = a < pow2i(-6.0)
    exp_field = jnp.where(is_sub, 0.0, e + 7.0)
    mant = jnp.where(
        is_sub,
        jnp.round(a * pow2i(9.0)),                  # subnormal step 2^-9
        jnp.round((a * pow2i(-e) - 1.0) * 8.0),     # 3-bit mantissa
    )
    code = (s << 7) | (exp_field.astype(jnp.uint8) << 3) | mant.astype(jnp.uint8)
    return code.astype(jnp.uint8)


def decode_e4m3(code):
    """Decode E4M3 bit codes (uint8) to float32."""
    code = jnp.asarray(code, jnp.uint8)
    s = ((code >> 7) & 1).astype(jnp.float32)
    e = ((code >> 3) & 0x0F).astype(jnp.float32)
    m = (code & 0x07).astype(jnp.float32)
    sub = m * pow2i(-9.0)
    norm = (1.0 + m / 8.0) * pow2i(e - 7.0)
    mag = jnp.where(e == 0, sub, norm)
    return jnp.where(s == 1, -mag, mag)


def encode_e5m2(x):
    """Encode float32 into E5M2 bit codes (uint8)."""
    q = quantize_e5m2(x)
    s = (q < 0).astype(jnp.uint8)
    a = jnp.abs(q)
    e = jnp.clip(_floor_log2(a), -14, 15)
    is_sub = a < pow2i(-14.0)
    exp_field = jnp.where(is_sub, 0.0, e + 15.0)
    mant = jnp.where(
        is_sub,
        jnp.round(a * pow2i(16.0)),                 # subnormal step 2^-16
        jnp.round((a * pow2i(-e) - 1.0) * 4.0),     # 2-bit mantissa
    )
    code = (s << 7) | (exp_field.astype(jnp.uint8) << 2) | mant.astype(jnp.uint8)
    return code.astype(jnp.uint8)


def decode_e5m2(code):
    """Decode E5M2 bit codes (uint8) to float32."""
    code = jnp.asarray(code, jnp.uint8)
    s = ((code >> 7) & 1).astype(jnp.float32)
    e = ((code >> 2) & 0x1F).astype(jnp.float32)
    m = (code & 0x03).astype(jnp.float32)
    sub = m * pow2i(-16.0)
    norm = (1.0 + m / 4.0) * pow2i(e - 15.0)
    mag = jnp.where(e == 0, sub, norm)
    return jnp.where(s == 1, -mag, mag)


# ---------------------------------------------------------------------------
# Shared scales (Algorithm 2, Steps 3 / 6 / 7)
# ---------------------------------------------------------------------------

def e8m0_shared_scale(block_amax, emax):
    """E8M0 shared exponent for MXFP blocks (Alg. 2, Step 6 + Step 7).

    Returns ``(scale_pow2, code)`` where ``scale_pow2`` is the float scale
    ``2^S_shared`` and ``code`` is the biased uint8 E8M0 representation
    (``S_shared + 127`` clamped to [0, 254]; 255 is reserved for NaN).
    """
    s_shared = _floor_log2(jnp.maximum(block_amax, _EPS)) - emax
    code = jnp.clip(s_shared + 127.0, 0.0, 254.0)
    s_shared = code - 127.0  # clamping must round-trip through the code
    return pow2i(s_shared), code.astype(jnp.uint8)


def nvfp4_shared_scale(block_amax):
    """NVFP4 per-16-block scale, stored in E4M3 (Alg. 2, Step 3).

    ``S_FP4 = amax / 6`` quantized onto the E4M3 grid so the stored byte
    and the dequantization factor agree bit-for-bit.
    """
    raw = block_amax / E2M1_MAX
    q = quantize_e4m3(raw)
    # A zero/degenerate block would give scale 0; use the smallest E4M3
    # subnormal instead so dequantization never divides by zero.
    q = jnp.maximum(q, pow2i(-9.0))
    return q, encode_e4m3(q)


# ---------------------------------------------------------------------------
# Block fake-quantization (format zoo, value level)
# ---------------------------------------------------------------------------

def _blockify(x, block):
    """Reshape [..., D] -> [..., D // block, block] (D must divide)."""
    d = x.shape[-1]
    assert d % block == 0, f"last dim {d} not divisible by block {block}"
    return x.reshape(*x.shape[:-1], d // block, block)


def _unblockify(xb):
    return xb.reshape(*xb.shape[:-2], xb.shape[-2] * xb.shape[-1])


def fake_quant_mxfp4(x):
    """MXFP4: E2M1 elements, E8M0 scale per 32-block (quantize->dequantize)."""
    xb = _blockify(jnp.asarray(x, jnp.float32), MXFP_BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale, _ = e8m0_shared_scale(amax, E2M1_EMAX)
    q = quantize_e2m1(xb / scale)
    return _unblockify(q * scale)


def fake_quant_mxfp8(x, element="e4m3"):
    """MXFP8: E4M3/E5M2 elements, E8M0 scale per 32-block."""
    xb = _blockify(jnp.asarray(x, jnp.float32), MXFP_BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    if element == "e4m3":
        scale, _ = e8m0_shared_scale(amax, E4M3_EMAX)
        q = quantize_e4m3(jnp.clip(xb / scale, -E4M3_MAX, E4M3_MAX))
    elif element == "e5m2":
        scale, _ = e8m0_shared_scale(amax, E5M2_EMAX)
        q = quantize_e5m2(jnp.clip(xb / scale, -E5M2_MAX, E5M2_MAX))
    else:
        raise ValueError(f"unknown element format {element!r}")
    return _unblockify(q * scale)


def fake_quant_nvfp4(x, tokenwise=False):
    """NVFP4: E2M1 elements, E4M3 scale per 16-block.

    With ``tokenwise=True`` an additional per-row quantization scale
    ``S_q = amax_row / (448 * 6)`` is applied first (Alg. 2, Step 2) —
    the "+" rows of Table 2 and the scheme DMA itself uses.
    """
    x = jnp.asarray(x, jnp.float32)
    if tokenwise:
        sq = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / (E4M3_MAX * E2M1_MAX)
        sq = jnp.maximum(sq, _EPS)
    else:
        sq = jnp.ones_like(x[..., :1])
    xs = x / sq
    xb = _blockify(xs, NVFP4_BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale, _ = nvfp4_shared_scale(amax)
    q = quantize_e2m1(jnp.clip(xb / scale, -E2M1_MAX, E2M1_MAX))
    return _unblockify(q * scale) * sq
