"""Full-precision tiled FlashAttention baseline (Pallas, interpret mode).

Same tiling and OnlineSoftmax structure as the DMA kernel but with f32
operands and the standard base-e softmax — this is the "Native"
(SDPA-equivalent) baseline of the paper's Tables 3 and 4, implemented in
the same framework so kernel-structure overheads cancel in comparisons.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bm, bn, d, lq, lk, causal):
    i = pl.program_id(0)
    off = lk - lq
    nk = lk // bn

    q = q_ref[...] * (1.0 / jnp.sqrt(jnp.float32(d)))
    row_ids = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    col_base = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)

    def step(j, carry):
        m, l, acc = carry
        ks = pl.ds(j * bn, bn)
        k_tile = k_ref[ks, :]
        s = jax.lax.dot_general(
            q, k_tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if causal:
            cols = j * bn + col_base
            s = jnp.where(cols <= row_ids + off, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[ks, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    frontier = i * bm + (bm - 1) + off
    j_end = jnp.minimum(frontier // bn + 1, nk) if causal else jnp.int32(nk)
    carry = (
        jnp.full((bm,), NEG_INF, jnp.float32),
        jnp.zeros((bm,), jnp.float32),
        jnp.zeros((bm, d), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(0, j_end, step, carry)
    o_ref[...] = acc / l[:, None]


def flash_attention(q, k, v, *, bm=64, bn=64, causal=True, interpret=True):
    """Tiled exact attention. q:[Lq,D], k,v:[Lk,D] -> [Lq,D] float32."""
    lq, d = q.shape
    lk = k.shape[0]
    assert lq % bm == 0 and lk % bn == 0, (lq, bm, lk, bn)
    kernel = functools.partial(
        _flash_kernel, bm=bm, bn=bn, d=d, lq=lq, lk=lk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(lq // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((lk, d), lambda i: (0, 0)),
            pl.BlockSpec((lk, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lq, d), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))


def flash_attention_mha(q, k, v, **kw):
    """Multi-head wrapper: [H, L, D] inputs, vmapped over heads."""
    return jax.vmap(lambda qq, kk, vv: flash_attention(qq, kk, vv, **kw))(q, k, v)
