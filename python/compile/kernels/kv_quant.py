"""MXFP-quantized paged KV cache — parity reference for ``rust/src/kvquant``.

The serving path stores decode-time K/V in pages of ``page_tokens`` rows,
quantized on append with the fused dual quantizer (Alg. 2): an MXFP8 high
copy (E4M3 codes + E8M0 block exponents) and/or an NVFP4 low copy (packed
E2M1 nibbles + E4M3 block scales), sharing one per-token scale ``S_q``.
Because ``S_q`` is per-token, appending rows in any chunking produces
bit-identical planes to quantizing the whole matrix at once — the
invariant that makes an *appendable* quantized cache possible.

At decode time the paper's diagonal-tile precision policy is applied to
cache *pages* instead of attention tiles: pages overlapping the attention
sink and the causal-frontier window decode MXFP8-high, everything in
between decodes NVFP4-low, page by page, with no full-precision K/V
materialization (only one page of scratch at a time).

Formats
-------
``"dual"``        both copies retained (policy picks per page),
``"mxfp8-high"``  only the MXFP8 copy (every page decodes high),
``"nvfp4-low"``   only the NVFP4 copy (every page decodes low).

This module is the cross-language oracle: ``rust/src/kvquant`` must
produce bit-identical code planes and matching page-precision schedules
(see ``python/tests/gen_golden_kvquant.py`` and
``rust/tests/kvquant_parity.rs``).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import mxfp
from . import quant_fused

FORMATS = ("dual", "mxfp8-high", "nvfp4-low")

#: Default page size in tokens; matches the Rust engine's KV block size so
#: pages align with BlockPool admission blocks.
PAGE_TOKENS = 16


def has_low(fmt: str) -> bool:
    """Does ``fmt`` retain the NVFP4 low-precision copy?"""
    return fmt in ("dual", "nvfp4-low")


def has_high(fmt: str) -> bool:
    """Does ``fmt`` retain the MXFP8 high-precision copy?"""
    return fmt in ("dual", "mxfp8-high")


def row_bytes(fmt: str, d: int) -> int:
    """Stored bytes per cached K (or V) row of width ``d``.

    Mirrors ``KvFormat::row_bytes`` in Rust: retained code planes plus the
    4-byte per-token scale S_q (shared by both copies).
    """
    b = 4  # S_q
    if has_low(fmt):
        b += d // 2 + d // mxfp.NVFP4_BLOCK
    if has_high(fmt):
        b += d + d // mxfp.MXFP_BLOCK
    return b


def f32_row_bytes(d: int) -> int:
    return 4 * d


def page_precisions(n_tokens: int, page_tokens: int, sink: int, diag: int,
                    frontier: int | None = None):
    """Per-page precision schedule for a query at its causal frontier.

    Derived from the phase boundaries of the DMA attention kernel
    (Alg. 1) with one query tile whose causal frontier is token
    ``frontier`` (default ``n_tokens - 1``, a decode step) and KV tile
    size ``page_tokens``:

      Phase 0  pages overlapping the first ``sink`` tokens    -> "high"
      Phase 1  pages before the diagonal window               -> "low"
      Phase 2  pages inside the ``diag``-token window ending at the
               frontier                                        -> "high"

    ``frontier`` may lie beyond the cached range — a prefill chunk
    attending its quantized prefix, or a long sequence attending pages
    shared from a shorter one. This position-awareness is what keeps a
    shared body page decoding "low" for a sequence whose own frontier is
    far past it, even if a shorter sharer sees the same page as
    "frontier". Returns a list of ``"high"`` / ``"low"`` strings, one per
    page.
    """
    p = page_tokens
    if frontier is None:
        frontier = n_tokens - 1
    n_pages = -(-n_tokens // p)
    n_sink = -(-sink // p) if sink > 0 else 0
    n_sink_eff = min(n_sink, n_pages)
    if diag == 0:
        j_hi_start = n_pages
    else:
        # Window start token is frontier - diag + 1; floor-divide
        # (matches Rust div_euclid for negative starts).
        j_hi_start = (frontier + 1 - diag) // p
        j_hi_start = min(max(j_hi_start, n_sink_eff), n_pages)
    return [
        "high" if (j < n_sink_eff or j >= j_hi_start) else "low"
        for j in range(n_pages)
    ]


class PagedKvCache:
    """Appendable dual-format quantized row store for one (layer, head).

    Rows are quantized on append; only the planes required by ``fmt`` are
    retained. Pages are logical ``page_tokens``-row ranges over the
    contiguous planes (no per-page allocation).
    """

    def __init__(self, d: int, fmt: str = "dual", page_tokens: int = PAGE_TOKENS):
        assert fmt in FORMATS, f"unknown kv format {fmt!r}"
        assert d % mxfp.MXFP_BLOCK == 0, f"d={d} must be a multiple of 32"
        self.d = d
        self.fmt = fmt
        self.page_tokens = page_tokens
        self.n = 0
        self.packed = np.zeros((0, d // 2), np.uint8)
        self.s4 = np.zeros((0, d // mxfp.NVFP4_BLOCK), np.uint8)
        self.fp8 = np.zeros((0, d), np.uint8)
        self.s8 = np.zeros((0, d // mxfp.MXFP_BLOCK), np.uint8)
        self.sq = np.zeros((0, 1), np.float32)

    def append(self, rows) -> None:
        """Quantize and append ``rows``: [n, d] float32 (keys: no softmax
        pre-scale — V rows use the identical path). A flat [n * d] vector
        is accepted; a 2-D array must already be d wide."""
        rows = np.asarray(rows, np.float32)
        assert rows.ndim <= 2, f"rows must be 1-D or 2-D, got {rows.shape}"
        if rows.ndim == 2:
            assert rows.shape[1] == self.d, \
                f"row width {rows.shape[1]} != d {self.d}"
        rows = rows.reshape(-1, self.d)
        if rows.shape[0] == 0:
            return
        pk, s4, f8, s8, sq = (
            np.asarray(a)
            for a in quant_fused.dual_quant(jnp.asarray(rows), is_query=False)
        )
        if has_low(self.fmt):
            self.packed = np.concatenate([self.packed, pk])
            self.s4 = np.concatenate([self.s4, s4])
        if has_high(self.fmt):
            self.fp8 = np.concatenate([self.fp8, f8])
            self.s8 = np.concatenate([self.s8, s8])
        self.sq = np.concatenate([self.sq, sq])
        self.n += rows.shape[0]

    @property
    def n_pages(self) -> int:
        return -(-self.n // self.page_tokens)

    def page_rows(self, j: int):
        """Row range [r0, r1) of page ``j`` (last page may be partial)."""
        r0 = j * self.page_tokens
        return r0, min(r0 + self.page_tokens, self.n)

    def nbytes(self) -> int:
        """Stored bytes (code planes + scales)."""
        return (
            self.packed.size + self.s4.size + self.fp8.size + self.s8.size
            + self.sq.size * 4
        )

    def effective(self, precision: str) -> str:
        """Clamp a requested precision to the copies this format retains."""
        if precision == "high" and not has_high(self.fmt):
            return "low"
        if precision == "low" and not has_low(self.fmt):
            return "high"
        return precision

    def decode_rows(self, r0: int, r1: int, precision: str) -> np.ndarray:
        """Dequantize rows [r0, r1) at ``precision`` (after clamping)."""
        precision = self.effective(precision)
        if precision == "high":
            out = quant_fused.dequant_mxfp8(
                jnp.asarray(self.fp8[r0:r1]),
                jnp.asarray(self.s8[r0:r1]),
                jnp.asarray(self.sq[r0:r1]),
            )
        else:
            out = quant_fused.dequant_nvfp4(
                jnp.asarray(self.packed[r0:r1]),
                jnp.asarray(self.s4[r0:r1]),
                jnp.asarray(self.sq[r0:r1]),
            )
        return np.asarray(out, np.float32)


def paged_decode_attention(q_row, cache_k: PagedKvCache, cache_v: PagedKvCache,
                           *, sink: int, diag: int, counters=None):
    """One decode step of DMA attention over a quantized paged cache.

    ``q_row``: [d] float32 query at position ``cache_k.n - 1``. The query
    is dual-quantized (softmax scale folded, Alg. 2 Step 1) and each page
    is decoded just before its matvec — K at the policy's precision, V at
    the highest precision its format retains — stitched with base-2
    online softmax. Returns [d] float32.

    ``counters``, if given, is a dict accumulating ``"high"``/``"low"``
    page-decode hit counts (the serving metrics' per-precision counters).
    """
    d, n = cache_k.d, cache_k.n
    assert n > 0 and cache_v.n == n and cache_v.d == d
    q = np.asarray(q_row, np.float32).reshape(1, d)
    qpk, qs4, qf8, qs8, qsq = (
        np.asarray(a) for a in quant_fused.dual_quant(jnp.asarray(q), is_query=True)
    )
    q_low = np.asarray(
        quant_fused.dequant_nvfp4(jnp.asarray(qpk), jnp.asarray(qs4), jnp.asarray(qsq)),
        np.float32)[0]
    q_high = np.asarray(
        quant_fused.dequant_mxfp8(jnp.asarray(qf8), jnp.asarray(qs8), jnp.asarray(qsq)),
        np.float32)[0]

    m = np.float32(-np.inf)
    l = np.float32(0.0)
    acc = np.zeros(d, np.float32)
    for j, prec in enumerate(page_precisions(n, cache_k.page_tokens, sink, diag)):
        r0, r1 = cache_k.page_rows(j)
        eff = cache_k.effective(prec)
        k_tile = cache_k.decode_rows(r0, r1, eff)
        q_dec = q_high if eff == "high" else q_low
        if counters is not None:
            counters[eff] = counters.get(eff, 0) + 1
        s = (k_tile @ q_dec).astype(np.float32)  # base-2 logits
        m_new = np.float32(max(m, s.max()))
        alpha = np.float32(0.0) if np.isneginf(m) else np.float32(np.exp2(m - m_new))
        p = np.exp2(s - m_new).astype(np.float32)
        l = l * alpha + p.sum(dtype=np.float32)
        v_tile = cache_v.decode_rows(r0, r1, "high")
        acc = acc * alpha + p @ v_tile
        m = m_new
    return acc / l


def chunked_prefill_attention(q_chunk, k_chunk, v_chunk,
                              cache_k: PagedKvCache, cache_v: PagedKvCache,
                              *, sink: int, diag: int, counters=None):
    """One chunk of streaming prefill attention over a quantized prefix.

    ``q_chunk``/``k_chunk``/``v_chunk``: ``[c, d]`` float32 post-RoPE
    tiles for the chunk at absolute positions
    ``[cache_k.n, cache_k.n + c)`` — everything already in the caches is
    prefix. The caller appends the chunk's K/V rows *after* this call
    (the caches are authoritative for the prefix only while scoring).

    Prefix pages decode at the position-aware policy precision
    (:func:`page_precisions` with the chunk's frontier), scored against
    the dual-quantized query copy of the matching precision — the decode
    kernel's arithmetic. The in-chunk causal triangle is scored in f32
    with the base-2 softmax scale folded in, and both parts stitch
    through one base-2 online softmax. Prefix V decodes high; chunk V
    stays f32. Returns ``[c, d]`` float32.

    This is the parity reference for
    ``rust/src/attention/paged.rs::dma_attention_prefill_chunk``.
    """
    d, pos0 = cache_k.d, cache_k.n
    assert cache_v.n == pos0 and cache_v.d == d
    q = np.asarray(q_chunk, np.float32).reshape(-1, d)
    kc = np.asarray(k_chunk, np.float32).reshape(-1, d)
    vc = np.asarray(v_chunk, np.float32).reshape(-1, d)
    c = q.shape[0]
    assert c >= 1 and kc.shape[0] == c and vc.shape[0] == c

    qpk, qs4, qf8, qs8, qsq = (
        np.asarray(a) for a in quant_fused.dual_quant(jnp.asarray(q), is_query=True)
    )
    q_low = np.asarray(
        quant_fused.dequant_nvfp4(jnp.asarray(qpk), jnp.asarray(qs4), jnp.asarray(qsq)),
        np.float32)
    q_high = np.asarray(
        quant_fused.dequant_mxfp8(jnp.asarray(qf8), jnp.asarray(qs8), jnp.asarray(qsq)),
        np.float32)

    m = np.full(c, -np.inf, np.float32)
    l = np.zeros(c, np.float32)
    acc = np.zeros((c, d), np.float32)

    def update(s, v_tile):
        # Base-2 online-softmax tile update ([c, cols] logits, -inf mask).
        nonlocal m, l, acc
        m_new = np.maximum(m, s.max(axis=1)).astype(np.float32)
        alpha = np.where(np.isneginf(m), np.float32(0.0),
                         np.exp2(m - m_new)).astype(np.float32)
        p = np.exp2(s - m_new[:, None]).astype(np.float32)  # exp2(-inf) = 0
        l[:] = l * alpha + p.sum(axis=1, dtype=np.float32)
        acc[:] = acc * alpha[:, None] + p @ v_tile
        m[:] = m_new

    # Prefix pages at the position-aware precision (no causal masking:
    # every prefix key precedes every chunk query).
    for j, prec in enumerate(page_precisions(pos0, cache_k.page_tokens,
                                             sink, diag,
                                             frontier=pos0 + c - 1)):
        r0, r1 = cache_k.page_rows(j)
        eff = cache_k.effective(prec)
        if counters is not None:
            counters[eff] = counters.get(eff, 0) + 1
        k_tile = cache_k.decode_rows(r0, r1, eff)
        q_dec = q_high if eff == "high" else q_low
        update((q_dec @ k_tile.T).astype(np.float32),
               cache_v.decode_rows(r0, r1, "high"))

    # The chunk's own causal triangle in f32, base-2 logits.
    pre = np.float32(np.log2(np.float32(np.e)) / np.sqrt(np.float32(d)))
    s = ((q @ kc.T).astype(np.float32) * pre).astype(np.float32)
    s[np.triu(np.ones((c, c), dtype=bool), 1)] = -np.inf
    update(s, vc)
    return acc / l[:, None]
