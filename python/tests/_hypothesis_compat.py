"""Optional-hypothesis shim: the property sweeps need `hypothesis`, but
the rest of each module must stay collectible without it. Import `given`,
`settings`, `st` from here; when hypothesis is absent the decorated tests
are skipped instead of breaking collection."""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — CI images without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
