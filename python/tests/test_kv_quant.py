"""Tests for the MXFP-quantized paged KV cache (`kv_quant.py`).

Covers the three contracts the Rust subsystem mirrors:

  1. append-chunking invariance (per-token S_q => planes identical no
     matter how rows arrive),
  2. the page precision policy matches the DMA kernel's phase boundaries,
  3. paged decode attention over a quantized cache equals the contiguous
     DMA attention kernel on the equivalent contiguous layout.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import dma_attention, kv_quant, mxfp, quant_fused


def rng(seed=0):
    return np.random.default_rng(seed)


def filled_cache(n, d, fmt="dual", page_tokens=8, seed=1, chunks=None):
    """A cache of n random rows appended in the given chunk sizes."""
    r = rng(seed)
    rows = r.standard_normal((n, d)).astype(np.float32)
    c = kv_quant.PagedKvCache(d, fmt, page_tokens)
    if chunks is None:
        chunks = [n]
    assert sum(chunks) == n
    i = 0
    for ch in chunks:
        c.append(rows[i:i + ch])
        i += ch
    return rows, c


# ---------------------------------------------------------------------------
# Storage / accounting
# ---------------------------------------------------------------------------

class TestStore:
    def test_append_chunking_invariant(self):
        """Appending token-by-token must produce bit-identical planes to
        one bulk append (per-token granularity guarantees this)."""
        n, d = 13, 32
        rows, bulk = filled_cache(n, d, "dual", 4, seed=3)
        _, steps = filled_cache(n, d, "dual", 4, seed=3,
                                chunks=[1] * n)
        np.testing.assert_array_equal(bulk.packed, steps.packed)
        np.testing.assert_array_equal(bulk.s4, steps.s4)
        np.testing.assert_array_equal(bulk.fp8, steps.fp8)
        np.testing.assert_array_equal(bulk.s8, steps.s8)
        np.testing.assert_array_equal(bulk.sq, steps.sq)

    def test_planes_match_bulk_dual_quant(self):
        n, d = 24, 64
        rows, c = filled_cache(n, d, "dual", 8, seed=4, chunks=[5, 11, 8])
        pk, s4, f8, s8, sq = quant_fused.dual_quant(
            jnp.asarray(rows), is_query=False)
        np.testing.assert_array_equal(c.packed, np.asarray(pk))
        np.testing.assert_array_equal(c.fp8, np.asarray(f8))
        np.testing.assert_array_equal(c.sq, np.asarray(sq))

    def test_single_format_drops_other_planes(self):
        _, lo = filled_cache(16, 32, "nvfp4-low", 8)
        assert lo.fp8.size == 0 and lo.s8.size == 0
        assert lo.packed.size == 16 * 16
        _, hi = filled_cache(16, 32, "mxfp8-high", 8)
        assert hi.packed.size == 0 and hi.s4.size == 0
        assert hi.fp8.size == 16 * 32

    def test_bytes_per_token_ratios(self):
        """nvfp4-low must be >= 3x (actually ~6x) smaller than f32; the
        engine's admission accounting relies on these exact numbers."""
        for d in (32, 64, 128):
            f32 = kv_quant.f32_row_bytes(d)
            assert f32 >= 3 * kv_quant.row_bytes("nvfp4-low", d)
            assert f32 >= 3 * kv_quant.row_bytes("mxfp8-high", d)
            assert kv_quant.row_bytes("dual", d) < f32
        # Stored bytes agree with the accounting formula.
        n, d = 32, 64
        for fmt in kv_quant.FORMATS:
            _, c = filled_cache(n, d, fmt, 8)
            assert c.nbytes() == n * kv_quant.row_bytes(fmt, d)

    def test_partial_page_rows(self):
        _, c = filled_cache(19, 32, "dual", 8)
        assert c.n_pages == 3
        assert c.page_rows(0) == (0, 8)
        assert c.page_rows(2) == (16, 19)

    def test_decode_rows_reconstructs(self):
        n, d = 16, 32
        rows, c = filled_cache(n, d, "dual", 8, seed=9)
        hi = c.decode_rows(0, n, "high")
        lo = c.decode_rows(0, n, "low")
        def rel(a, b):
            return np.linalg.norm(a - b) / np.linalg.norm(a)
        assert rel(rows, hi) < 0.05
        assert rel(rows, lo) < 0.25
        assert rel(rows, hi) < rel(rows, lo)

    def test_effective_precision_clamps_to_format(self):
        _, lo = filled_cache(8, 32, "nvfp4-low", 8)
        assert lo.effective("high") == "low"
        _, hi = filled_cache(8, 32, "mxfp8-high", 8)
        assert hi.effective("low") == "high"
        _, du = filled_cache(8, 32, "dual", 8)
        assert du.effective("high") == "high"
        assert du.effective("low") == "low"


# ---------------------------------------------------------------------------
# Page precision policy
# ---------------------------------------------------------------------------

class TestPolicy:
    def test_sink_and_frontier_high(self):
        p = kv_quant.page_precisions(64, 8, sink=8, diag=16)
        assert p[0] == "high"           # sink page
        assert p[-1] == "high"          # frontier page
        assert p[-2] == "high"          # diag=16 covers two 8-token pages
        assert all(x == "low" for x in p[1:-2])

    def test_diag_zero_all_low(self):
        assert kv_quant.page_precisions(64, 8, sink=0, diag=0) == ["low"] * 8

    def test_small_cache_all_high(self):
        # Cache shorter than the window: everything decodes high.
        assert kv_quant.page_precisions(16, 8, sink=0, diag=64) == ["high"] * 2

    def test_sink_rounds_up_to_page(self):
        p = kv_quant.page_precisions(64, 8, sink=9, diag=8)
        assert p[0] == "high" and p[1] == "high"  # ceil(9/8) = 2 pages

    def test_position_aware_frontier(self):
        """The same cached pages decode differently depending on where
        the querying sequence's frontier sits — a shared body page inside
        a short sequence's diag window is still low for a longer one."""
        near = kv_quant.page_precisions(32, 8, sink=8, diag=16, frontier=31)
        assert near == ["high", "low", "high", "high"]
        far = kv_quant.page_precisions(32, 8, sink=8, diag=16, frontier=127)
        assert far == ["high", "low", "low", "low"]
        # Default frontier is the last cached token (the decode schedule).
        assert (kv_quant.page_precisions(64, 8, sink=8, diag=16, frontier=63)
                == kv_quant.page_precisions(64, 8, sink=8, diag=16))
        # A frontier beyond a short prefix with a window reaching back in.
        reach = kv_quant.page_precisions(16, 8, sink=0, diag=16, frontier=23)
        assert reach == ["low", "high"]

    def test_matches_dma_kernel_phases(self):
        """The page schedule must equal the tile schedule the contiguous
        DMA kernel uses for a decode query at the frontier (bm=1)."""
        for n, p, sink, diag in [(64, 8, 8, 16), (96, 16, 32, 32),
                                 (40, 8, 0, 24), (64, 8, 64, 0)]:
            precs = kv_quant.page_precisions(n, p, sink, diag)
            # Re-derive from the kernel's own boundary arithmetic
            # (dma_attention.py::_dma_kernel, causal branch, lq=1).
            frontier = n - 1
            nk = -(-n // p)
            j_end = min(frontier // p + 1, nk)
            n_sink = -(-sink // p) if sink > 0 else 0
            n_sink_eff = min(n_sink, j_end)
            j_hi = (frontier - diag + 1) // p if diag > 0 else j_end
            j_hi = min(max(j_hi, n_sink_eff), j_end)
            expect = ["high" if (j < n_sink_eff or j >= j_hi) else "low"
                      for j in range(j_end)]
            assert precs == expect, (n, p, sink, diag)


# ---------------------------------------------------------------------------
# Paged decode attention
# ---------------------------------------------------------------------------

class TestPagedAttention:
    def _paged_vs_contiguous(self, fmt, n=64, d=32, page=8, sink=8, diag=16,
                             seed=11):
        r = rng(seed)
        k_rows, ck = filled_cache(n, d, fmt, page, seed=seed,
                                  chunks=[n // 2, n // 4, n // 4])
        v_rows, cv = filled_cache(n, d, fmt, page, seed=seed + 1)
        q_row = r.standard_normal(d).astype(np.float32)

        counters = {}
        out = kv_quant.paged_decode_attention(
            q_row, ck, cv, sink=sink, diag=diag, counters=counters)

        # Equivalent contiguous layout: same K code planes, V as the exact
        # dequantization the paged path uses.
        q_quant = quant_fused.dual_quant(
            jnp.asarray(q_row.reshape(1, d)), is_query=True)
        k_quant = (jnp.asarray(ck.packed), jnp.asarray(ck.s4),
                   jnp.asarray(ck.fp8), jnp.asarray(ck.s8),
                   jnp.asarray(ck.sq))
        v_eq = jnp.asarray(cv.decode_rows(0, n, "high"))
        ref = np.asarray(dma_attention.dma_attention_quantized(
            q_quant, k_quant, v_eq, bm=1, bn=page, diag=diag, sink=sink,
            causal=True))[0]
        np.testing.assert_allclose(out, ref, rtol=0, atol=2e-5)
        return counters

    def test_dual_matches_contiguous_kernel(self):
        counters = self._paged_vs_contiguous("dual")
        # sink page + two frontier pages high, five body pages low.
        assert counters == {"high": 3, "low": 5}

    def test_mixed_policies(self):
        for sink, diag in [(0, 0), (16, 0), (0, 32), (32, 32)]:
            self._paged_vs_contiguous("dual", sink=sink, diag=diag,
                                      seed=100 + sink + diag)

    def test_single_format_caches(self):
        # nvfp4-low / mxfp8-high: one copy only; the contiguous oracle
        # needs matching planes, so compare against a dual cache whose
        # policy is forced all-low / all-high instead.
        n, d, page = 48, 32, 8
        k_rows, ck_dual = filled_cache(n, d, "dual", page, seed=21)
        v_rows, cv_dual = filled_cache(n, d, "dual", page, seed=22)
        q_row = rng(23).standard_normal(d).astype(np.float32)

        _, ck_lo = filled_cache(n, d, "nvfp4-low", page, seed=21)
        _, cv_lo = filled_cache(n, d, "nvfp4-low", page, seed=22)
        out_lo = kv_quant.paged_decode_attention(
            q_row, ck_lo, cv_lo, sink=8, diag=16)
        # In a low-only cache the policy is moot: equals dual with diag=sink=0
        # except V also decodes low — rebuild the oracle with low V.
        c2 = {}
        out_dual_all_low = kv_quant.paged_decode_attention(
            q_row, ck_dual, _force_low_v(cv_dual), sink=0, diag=0, counters=c2)
        np.testing.assert_allclose(out_lo, out_dual_all_low, atol=2e-5)
        assert c2 == {"low": 6}

        _, ck_hi = filled_cache(n, d, "mxfp8-high", page, seed=21)
        _, cv_hi = filled_cache(n, d, "mxfp8-high", page, seed=22)
        out_hi = kv_quant.paged_decode_attention(
            q_row, ck_hi, cv_hi, sink=8, diag=16)
        out_dual_all_high = kv_quant.paged_decode_attention(
            q_row, ck_dual, cv_dual, sink=0, diag=10 ** 6)
        np.testing.assert_allclose(out_hi, out_dual_all_high, atol=2e-5)

    def test_partial_frontier_page(self):
        """Cache length not a multiple of the page size: the frontier page
        is partial; compare against a dense softmax oracle."""
        n, d, page = 27, 32, 8
        k_rows, ck = filled_cache(n, d, "dual", page, seed=31)
        v_rows, cv = filled_cache(n, d, "dual", page, seed=32)
        q_row = rng(33).standard_normal(d).astype(np.float32)
        out = kv_quant.paged_decode_attention(q_row, ck, cv, sink=8, diag=16)

        # Dense oracle on the decoded operands with the page-level mixture.
        qq = quant_fused.dual_quant(
            jnp.asarray(q_row.reshape(1, d)), is_query=True)
        qpk, qs4, qf8, qs8, qsq = qq
        ql = np.asarray(quant_fused.dequant_nvfp4(qpk, qs4, qsq))[0]
        qh = np.asarray(quant_fused.dequant_mxfp8(qf8, qs8, qsq))[0]
        precs = kv_quant.page_precisions(n, page, 8, 16)
        s = np.empty(n, np.float32)
        for j, pr in enumerate(precs):
            r0, r1 = ck.page_rows(j)
            kt = ck.decode_rows(r0, r1, pr)
            s[r0:r1] = kt @ (qh if pr == "high" else ql)
        p = np.exp2((s - s.max()).astype(np.float32))
        p /= p.sum()
        ref = p @ cv.decode_rows(0, n, "high")
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_precision_policy_quality_ordering(self):
        """The paper's claim at page granularity: all-high is close to
        exact f32 attention, and the sink+diagonal policy beats all-low."""
        n, d, page = 64, 32, 8
        k_rows, ck = filled_cache(n, d, "dual", page, seed=41)
        v_rows, cv = filled_cache(n, d, "dual", page, seed=42)

        err = {"dma": 0.0, "low": 0.0, "high": 0.0}
        cos_high = []
        for qi in range(8):
            q_row = rng(43 + qi).standard_normal(d).astype(np.float32)
            s = (k_rows @ q_row) / np.sqrt(d)
            p = np.exp(s - s.max())
            p /= p.sum()
            ref = p @ v_rows
            outs = {
                "dma": kv_quant.paged_decode_attention(
                    q_row, ck, cv, sink=8, diag=16),
                "low": kv_quant.paged_decode_attention(
                    q_row, ck, cv, sink=0, diag=0),
                "high": kv_quant.paged_decode_attention(
                    q_row, ck, cv, sink=0, diag=10 ** 6),
            }
            for k, o in outs.items():
                err[k] += float(np.linalg.norm(o - ref))
            cos_high.append(float(
                np.dot(outs["high"], ref)
                / (np.linalg.norm(outs["high"]) * np.linalg.norm(ref))))
        assert min(cos_high) > 0.995, cos_high
        assert err["high"] < err["dma"] < err["low"], err

    def test_requires_nonempty_cache(self):
        c = kv_quant.PagedKvCache(32, "dual", 8)
        with pytest.raises(AssertionError):
            kv_quant.paged_decode_attention(
                np.zeros(32, np.float32), c, c, sink=0, diag=0)


# ---------------------------------------------------------------------------
# Chunked prefill over a quantized prefix
# ---------------------------------------------------------------------------

class TestChunkedPrefill:
    def _stream(self, n, d, chunk, page, sink, diag, seed):
        r = rng(seed)
        k = r.standard_normal((n, d)).astype(np.float32)
        v = r.standard_normal((n, d)).astype(np.float32)
        q = r.standard_normal((n, d)).astype(np.float32)
        ck = kv_quant.PagedKvCache(d, "dual", page)
        cv = kv_quant.PagedKvCache(d, "dual", page)
        outs, counters = [], {}
        for p0 in range(0, n, chunk):
            outs.append(kv_quant.chunked_prefill_attention(
                q[p0:p0 + chunk], k[p0:p0 + chunk], v[p0:p0 + chunk],
                ck, cv, sink=sink, diag=diag, counters=counters))
            ck.append(k[p0:p0 + chunk])
            cv.append(v[p0:p0 + chunk])
        return k, v, q, ck, cv, np.concatenate(outs), counters

    def test_streamed_planes_bit_equal_bulk(self):
        """Quantize-on-append during chunked prefill must produce the
        same planes as bulk-quantizing all K rows at once — the invariant
        that makes chunked prefill bit-compatible with the monolithic
        prefill+quantize path."""
        k, _, _, ck, _, _, _ = self._stream(32, 32, 8, 8, 8, 16, seed=50)
        pk, s4, f8, s8, sq = quant_fused.dual_quant(
            jnp.asarray(k), is_query=False)
        np.testing.assert_array_equal(ck.packed, np.asarray(pk))
        np.testing.assert_array_equal(ck.fp8, np.asarray(f8))
        np.testing.assert_array_equal(ck.sq, np.asarray(sq))

    def test_first_chunk_is_pure_f32_triangle(self):
        """With an empty prefix the kernel reduces to exact causal
        attention on the f32 chunk operands (base-2 softmax)."""
        n, d = 8, 32
        r = rng(51)
        q = r.standard_normal((n, d)).astype(np.float32)
        k = r.standard_normal((n, d)).astype(np.float32)
        v = r.standard_normal((n, d)).astype(np.float32)
        ck = kv_quant.PagedKvCache(d, "dual", 8)
        cv = kv_quant.PagedKvCache(d, "dual", 8)
        counters = {}
        out = kv_quant.chunked_prefill_attention(
            q, k, v, ck, cv, sink=8, diag=8, counters=counters)
        assert counters == {}
        s = (q @ k.T) / np.sqrt(np.float32(d))
        s[np.triu(np.ones((n, n), dtype=bool), 1)] = -np.inf
        p = np.exp(s - s.max(axis=1, keepdims=True))
        p /= p.sum(axis=1, keepdims=True)
        np.testing.assert_allclose(out, p @ v, atol=2e-5)

    def test_chunk_matches_dense_mixed_oracle(self):
        """A chunk over a quantized prefix equals a one-shot base-2
        softmax over the page-mixed prefix + f32 chunk logits."""
        n, d, chunk, page, sink, diag = 32, 32, 8, 8, 8, 16
        k, v, q, ck, cv, outs, counters = self._stream(
            n, d, chunk, page, sink, diag, seed=52)
        assert counters["high"] + counters["low"] == 1 + 2 + 3

        # Re-derive the last chunk from decoded operands.
        p0 = n - chunk
        ck2 = kv_quant.PagedKvCache(d, "dual", page)
        cv2 = kv_quant.PagedKvCache(d, "dual", page)
        ck2.append(k[:p0])
        cv2.append(v[:p0])
        qq = quant_fused.dual_quant(jnp.asarray(q[p0:]), is_query=True)
        qpk, qs4, qf8, qs8, qsq = qq
        ql = np.asarray(quant_fused.dequant_nvfp4(qpk, qs4, qsq))
        qh = np.asarray(quant_fused.dequant_mxfp8(qf8, qs8, qsq))
        precs = kv_quant.page_precisions(p0, page, sink, diag,
                                         frontier=n - 1)
        pre = np.float32(np.log2(np.float32(np.e)) / np.sqrt(np.float32(d)))
        s = np.full((chunk, n), -np.inf, np.float32)
        for j, pr in enumerate(precs):
            r0, r1 = ck2.page_rows(j)
            kt = ck2.decode_rows(r0, r1, pr)
            qd = qh if pr == "high" else ql
            s[:, r0:r1] = qd @ kt.T
        tri = (q[p0:] @ k[p0:].T).astype(np.float32) * pre
        tri[np.triu(np.ones((chunk, chunk), dtype=bool), 1)] = -np.inf
        s[:, p0:] = tri
        p = np.exp2(s - s.max(axis=1, keepdims=True))
        p /= p.sum(axis=1, keepdims=True)
        p = np.nan_to_num(p)
        v_all = np.concatenate(
            [cv2.decode_rows(0, p0, "high"), v[p0:]], axis=0)
        ref = p @ v_all
        np.testing.assert_allclose(outs[p0:], ref, atol=2e-4)

    def test_shared_prefix_reproduces_cold_start(self):
        """Prefix-cache contract at the kernel level: importing another
        stream's prefix planes and prefilling only the suffix yields
        bit-identical planes and outputs to the cold run."""
        n, d, chunk, page = 32, 32, 8, 8
        k, v, q, ck, cv, outs, _ = self._stream(n, d, chunk, page, 8, 16,
                                                seed=53)
        shared = 16  # two full pages, chunk-aligned
        ck2 = kv_quant.PagedKvCache(d, "dual", page)
        cv2 = kv_quant.PagedKvCache(d, "dual", page)
        # Import the cold run's prefix planes (numpy slices share memory —
        # the zero-copy analogue of the Rust Arc pages).
        for cache, src in ((ck2, ck), (cv2, cv)):
            cache.packed = src.packed[:shared]
            cache.s4 = src.s4[:shared]
            cache.fp8 = src.fp8[:shared]
            cache.s8 = src.s8[:shared]
            cache.sq = src.sq[:shared]
            cache.n = shared
        warm_outs = []
        for p0 in range(shared, n, chunk):
            warm_outs.append(kv_quant.chunked_prefill_attention(
                q[p0:p0 + chunk], k[p0:p0 + chunk], v[p0:p0 + chunk],
                ck2, cv2, sink=8, diag=16))
            ck2.append(k[p0:p0 + chunk])
            cv2.append(v[p0:p0 + chunk])
        np.testing.assert_array_equal(np.concatenate(warm_outs),
                                      outs[shared:])
        np.testing.assert_array_equal(ck2.packed, ck.packed)
        np.testing.assert_array_equal(ck2.fp8, ck.fp8)


def _force_low_v(cache):
    """A view of a dual cache that decodes V low (test helper)."""
    import copy
    c = copy.copy(cache)
    c.fmt = "nvfp4-low"
    return c
