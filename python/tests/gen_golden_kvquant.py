"""Generate the cross-language golden vectors consumed by the Rust tests.

Writes (relative to the repository root):

  * ``rust/testdata/golden_mxfp.json``    — codec vectors for
    ``rust/tests/integration.rs`` (e2m1 / e4m3 / e5m2 / e8m0 /
    dual_quant),
  * ``rust/testdata/golden_kvquant.json`` — paged quantized KV-cache
    vectors for ``rust/tests/kvquant_parity.rs``.

The jnp implementations are the source of truth; the Rust mirrors must
reproduce the integer code planes bit-for-bit (modulo the documented
1-ulp S_q rounding ties) and the attention outputs numerically.

Run from the repository root:  python3 python/tests/gen_golden_kvquant.py
"""

import json
import os
import sys

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import kv_quant, mxfp, quant_fused  # noqa: E402


def f32s(a):
    """Floats serialized so f64 JSON round-trips to the exact f32."""
    return [float(np.float32(v)) for v in np.asarray(a, np.float32).ravel()]


def u8s(a):
    return [int(v) for v in np.asarray(a, np.uint8).ravel()]


def codec_vectors():
    r = np.random.default_rng(2026)

    def sweep(maxval):
        vals = np.concatenate([
            np.array([0.0, -0.0], np.float32),
            r.uniform(-maxval * 1.2, maxval * 1.2, 64).astype(np.float32),
            r.standard_normal(64).astype(np.float32),
            (r.standard_normal(32) * maxval / 4).astype(np.float32),
        ])
        return vals.astype(np.float32)

    out = {}
    x = np.concatenate([
        np.array([0.0, 0.25, 0.5, 1.0, 1.25, 1.5, 2.0, 2.5, 3.0, 4.0,
                  5.0, 6.0, 7.5, -5.0, -0.25, 1.75], np.float32),
        sweep(6.0),
    ])
    xc = np.clip(x, -6.0, 6.0)
    code = mxfp.encode_e2m1(jnp.asarray(xc))
    out["e2m1"] = {
        "input": f32s(x),
        "code": u8s(code),
        "decoded": f32s(mxfp.decode_e2m1(code)),
    }

    x = np.concatenate([
        np.array([0.0, 448.0, 500.0, -448.0, 0.001953125, 2.0 ** -9,
                  2.0 ** -6, 1.0, -1.0], np.float32),
        sweep(448.0),
    ])
    code = mxfp.encode_e4m3(jnp.asarray(x))
    out["e4m3"] = {
        "input": f32s(x),
        "code": u8s(code),
        "decoded": f32s(mxfp.decode_e4m3(code)),
    }

    x = np.concatenate([
        np.array([0.0, 57344.0, 60000.0, -57344.0, 2.0 ** -16, 2.0 ** -14,
                  1.0, -3.5], np.float32),
        sweep(57344.0),
    ])
    code = mxfp.encode_e5m2(jnp.asarray(x))
    out["e5m2"] = {
        "input": f32s(x),
        "code": u8s(code),
        "decoded": f32s(mxfp.decode_e5m2(code)),
    }

    cases = []
    for emax in (mxfp.E2M1_EMAX, mxfp.E4M3_EMAX):
        amax = np.concatenate([
            np.array([448.0, 6.0, 1.0, 0.0, 1e-30], np.float32),
            np.exp2(r.uniform(-40, 40, 64)).astype(np.float32),
        ])
        scale, code = mxfp.e8m0_shared_scale(jnp.asarray(amax), emax)
        cases.append({
            "emax": emax,
            "amax": f32s(amax),
            "scale": f32s(scale),
            "code": u8s(code),
        })
    out["e8m0"] = cases

    rows, d = 8, 64
    x = (r.standard_normal((rows, d)) * np.exp2(
        r.uniform(-2, 4, (rows, 1)))).astype(np.float32)
    dq = {"x": f32s(x), "rows": rows, "d": d}
    for tag, is_q in (("query", True), ("key", False)):
        pk, s4, f8, s8, sq = quant_fused.dual_quant(
            jnp.asarray(x), is_query=is_q)
        dq[tag] = {
            "packed": u8s(pk), "s4": u8s(s4), "fp8": u8s(f8),
            "s8": u8s(s8), "sq": f32s(sq),
        }
    out["dual_quant"] = dq
    return out


def kvquant_vectors():
    r = np.random.default_rng(7)
    d, page, sink, diag, n = 32, 8, 8, 16, 40
    chunks = [17, 13, 10]
    k_rows = r.standard_normal((n, d)).astype(np.float32)
    v_rows = r.standard_normal((n, d)).astype(np.float32)
    q_row = r.standard_normal(d).astype(np.float32)

    caches = {}
    for fmt in kv_quant.FORMATS:
        ck = kv_quant.PagedKvCache(d, fmt, page)
        cv = kv_quant.PagedKvCache(d, fmt, page)
        i = 0
        for ch in chunks:
            ck.append(k_rows[i:i + ch])
            cv.append(v_rows[i:i + ch])
            i += ch
        caches[fmt] = (ck, cv)

    ck, cv = caches["dual"]
    counters = {}
    out = kv_quant.paged_decode_attention(
        q_row, ck, cv, sink=sink, diag=diag, counters=counters)
    ck_lo, cv_lo = caches["nvfp4-low"]
    out_low = kv_quant.paged_decode_attention(
        q_row, ck_lo, cv_lo, sink=sink, diag=diag)

    return {
        "d": d, "page_tokens": page, "sink": sink, "diag": diag, "len": n,
        "append_chunks": chunks,
        "k": f32s(k_rows), "v": f32s(v_rows), "q": f32s(q_row),
        "k_planes": {
            "packed": u8s(ck.packed), "s4": u8s(ck.s4),
            "fp8": u8s(ck.fp8), "s8": u8s(ck.s8), "sq": f32s(ck.sq),
        },
        "bytes": {fmt: {"k": caches[fmt][0].nbytes(),
                        "v": caches[fmt][1].nbytes()}
                  for fmt in kv_quant.FORMATS},
        "page_precisions": kv_quant.page_precisions(n, page, sink, diag),
        "page_hits": counters,
        "out": f32s(out),
        "out_low": f32s(out_low),
        "chunked_prefill": chunked_prefill_vectors(),
    }


def chunked_prefill_vectors():
    """Streaming-prefill fixture: K/V/Q tiles fed chunk by chunk through
    ``chunked_prefill_attention`` + append, recording each chunk's output,
    the position-aware schedules, the page-hit counters and the final
    planes (consumed by ``golden_chunked_prefill_parity`` in
    ``rust/tests/kvquant_parity.rs``)."""
    r = np.random.default_rng(11)
    d, page, sink, diag = 32, 8, 8, 16
    chunk, n = 8, 32
    k_rows = r.standard_normal((n, d)).astype(np.float32)
    v_rows = r.standard_normal((n, d)).astype(np.float32)
    q_rows = r.standard_normal((n, d)).astype(np.float32)

    ck = kv_quant.PagedKvCache(d, "dual", page)
    cv = kv_quant.PagedKvCache(d, "dual", page)
    counters = {}
    chunk_outs, schedules = [], []
    for pos0 in range(0, n, chunk):
        schedules.append(kv_quant.page_precisions(
            pos0, page, sink, diag, frontier=pos0 + chunk - 1))
        out = kv_quant.chunked_prefill_attention(
            q_rows[pos0:pos0 + chunk], k_rows[pos0:pos0 + chunk],
            v_rows[pos0:pos0 + chunk], ck, cv,
            sink=sink, diag=diag, counters=counters)
        chunk_outs.append(f32s(out))
        ck.append(k_rows[pos0:pos0 + chunk])
        cv.append(v_rows[pos0:pos0 + chunk])

    return {
        "d": d, "page_tokens": page, "sink": sink, "diag": diag,
        "chunk_tokens": chunk,
        "k": f32s(k_rows), "v": f32s(v_rows), "q": f32s(q_rows),
        "chunk_outs": chunk_outs,
        "schedules": schedules,
        "page_hits": counters,
        "k_planes": {
            "packed": u8s(ck.packed), "fp8": u8s(ck.fp8), "s8": u8s(ck.s8),
        },
    }


def main():
    root = os.path.join(os.path.dirname(__file__), "..", "..")
    testdata = os.path.join(root, "rust", "testdata")
    os.makedirs(testdata, exist_ok=True)
    for name, payload in (
        ("golden_mxfp.json", codec_vectors()),
        ("golden_kvquant.json", kvquant_vectors()),
    ):
        path = os.path.join(testdata, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        print(f"wrote {os.path.relpath(path, root)}"
              f" ({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()
