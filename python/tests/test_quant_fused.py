"""Fused dual-quantization Pallas kernel vs the pure-jnp oracle.

Separately-compiled graphs may differ by 1 ulp in the per-token scale
``S_q``; a value sitting exactly on a rounding tie can then flip by one
quantization step. Tests therefore require (a) overwhelming elementwise
equality and (b) every mismatch bounded by one local grid step.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels import mxfp, ref, quant_fused as qf


def _random(l, d, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(l, d)).astype(np.float32)) * scale


def _assert_close_mod_ties(a, b, step_frac=0.25, max_mismatch=0.01):
    a, b = np.array(a), np.array(b)
    diff = np.abs(a - b)
    scale = np.maximum(np.abs(a), np.abs(b)) + 1e-9
    mismatched = diff > 1e-6 * scale
    frac = mismatched.mean()
    assert frac <= max_mismatch, f"{frac:.4%} elements differ"
    # Any mismatch must stay within a local quantization step.
    assert np.all(diff <= step_frac * scale + 1e-7), float(diff.max())


class TestDualQuantKernel:
    @pytest.mark.parametrize("is_query", [True, False])
    @pytest.mark.parametrize("l,d", [(64, 32), (128, 64), (256, 128)])
    def test_matches_reference(self, is_query, l, d):
        x = _random(l, d, seed=l + d)
        pk, s4, f8, s8, sq = qf.dual_quant(x, is_query=is_query)
        rl, rh, rsq = ref.dual_quant_ref(x, is_query=is_query)
        np.testing.assert_allclose(np.array(sq), np.array(rsq), rtol=1e-6)
        _assert_close_mod_ties(qf.dequant_nvfp4(pk, s4, sq), rl)
        _assert_close_mod_ties(qf.dequant_mxfp8(f8, s8, sq), rh)

    def test_output_shapes_and_dtypes(self):
        x = _random(128, 64)
        pk, s4, f8, s8, sq = qf.dual_quant(x, is_query=True)
        assert pk.shape == (128, 32) and pk.dtype == jnp.uint8
        assert s4.shape == (128, 4) and s4.dtype == jnp.uint8
        assert f8.shape == (128, 64) and f8.dtype == jnp.uint8
        assert s8.shape == (128, 2) and s8.dtype == jnp.uint8
        assert sq.shape == (128, 1) and sq.dtype == jnp.float32

    def test_query_prescale_applied(self):
        """Q path must fold log2(e)/sqrt(D) before quantization."""
        x = _random(64, 64, seed=3)
        pk, s4, f8, s8, sq = qf.dual_quant(x, is_query=True)
        xh = qf.dequant_mxfp8(f8, s8, sq)
        target = x * (mxfp.LOG2_E / np.sqrt(64.0))
        rel = float(jnp.linalg.norm(xh - target) / jnp.linalg.norm(target))
        assert rel < 0.05

    def test_key_path_no_prescale(self):
        x = _random(64, 64, seed=4)
        _, _, f8, s8, sq = qf.dual_quant(x, is_query=False)
        xh = qf.dequant_mxfp8(f8, s8, sq)
        rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
        assert rel < 0.05

    def test_low_copy_coarser_than_high(self):
        x = _random(128, 64, seed=5, scale=2.0)
        pk, s4, f8, s8, sq = qf.dual_quant(x, is_query=False)
        xl = qf.dequant_nvfp4(pk, s4, sq)
        xh = qf.dequant_mxfp8(f8, s8, sq)
        el = float(jnp.linalg.norm(xl - x))
        eh = float(jnp.linalg.norm(xh - x))
        assert el > 2 * eh, (el, eh)

    def test_grid_tiling_invariant(self):
        """Same result regardless of the row-tile size (fusion boundary)."""
        x = _random(256, 64, seed=6)
        outs = [qf.dual_quant(x, is_query=True, block_rows=r)
                for r in (32, 64, 128, 256)]
        for o in outs[1:]:
            for a, b in zip(outs[0], o):
                np.testing.assert_array_equal(np.array(a), np.array(b))

    def test_outlier_token_contained(self):
        """Per-token S_q localizes an outlier row's damage (Challenge 1)."""
        x = np.array(_random(64, 64, seed=7))
        x[11] *= 1000.0
        x = jnp.asarray(x)
        pk, s4, f8, s8, sq = qf.dual_quant(x, is_query=False)
        xl = qf.dequant_nvfp4(pk, s4, sq)
        other = [i for i in range(64) if i != 11]
        rel = float(jnp.linalg.norm(xl[other, :] - x[other, :])
                    / jnp.linalg.norm(x[other, :]))
        assert rel < 0.2

    @settings(max_examples=10, deadline=None)
    @given(
        l=st.sampled_from([32, 64, 96, 128]),
        d=st.sampled_from([32, 64, 96, 128]),
        scale=st.floats(0.01, 100.0),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_dtype_sweep(self, l, d, scale, seed):
        """The paper-mandated hypothesis sweep: arbitrary shapes/scales,
        kernel must reconstruct within NVFP4/MXFP8 error budgets."""
        x = _random(l, d, seed=seed, scale=scale)
        pk, s4, f8, s8, sq = qf.dual_quant(x, is_query=False)
        xl = qf.dequant_nvfp4(pk, s4, sq)
        xh = qf.dequant_mxfp8(f8, s8, sq)
        nx = float(jnp.linalg.norm(x)) + 1e-9
        assert float(jnp.linalg.norm(xl - x)) / nx < 0.25
        assert float(jnp.linalg.norm(xh - x)) / nx < 0.07
