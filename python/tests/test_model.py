"""Model (L2) tests: shapes, prefill/decode consistency, task generators."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import tasks


@pytest.fixture(scope="module")
def cfg():
    return M.ModelConfig()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(jax.random.PRNGKey(0), cfg)


def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, tasks.VOCAB, size=n), jnp.int32)


class TestForward:
    def test_logits_shape(self, cfg, params):
        lg = M.forward(params, _toks(64), cfg)
        assert lg.shape == (64, cfg.vocab)

    def test_batch_matches_single(self, cfg, params):
        t = _toks(32)
        lg1 = M.forward(params, t, cfg)
        lg2 = M.forward_batch(params, t[None, :], cfg)
        np.testing.assert_allclose(np.array(lg1), np.array(lg2[0]),
                                   rtol=1e-5, atol=1e-6)

    def test_causality(self, cfg, params):
        """Changing a future token must not affect earlier logits."""
        t = np.array(_toks(48))
        lg1 = M.forward(params, jnp.asarray(t), cfg)
        t2 = t.copy()
        t2[40] = (t2[40] + 1) % tasks.VOCAB
        lg2 = M.forward(params, jnp.asarray(t2), cfg)
        np.testing.assert_allclose(np.array(lg1[:40]), np.array(lg2[:40]),
                                   rtol=1e-5, atol=1e-6)
        assert not np.allclose(np.array(lg1[40:]), np.array(lg2[40:]))

    def test_dma_mode_close_to_native(self, cfg, params):
        t = _toks(64, seed=5)
        lg_n = M.forward(params, t, cfg, mode="native")
        lg_d = M.forward(params, t, cfg, mode="dma")
        # Same argmax for the overwhelming majority of positions.
        agree = float(np.mean(np.array(jnp.argmax(lg_n, -1))
                              == np.array(jnp.argmax(lg_d, -1))))
        assert agree > 0.9, agree


class TestPrefillDecode:
    def test_prefill_matches_forward(self, cfg, params):
        t = _toks(64, seed=1)
        lg_f = M.forward(params, t, cfg)
        lg_p, kc, vc = M.prefill(params, t, cfg)
        np.testing.assert_allclose(np.array(lg_f), np.array(lg_p),
                                   rtol=1e-5, atol=1e-6)
        assert kc.shape == (cfg.n_layers, cfg.n_kv_heads, 64, cfg.d_head)

    def test_decode_continues_prefill(self, cfg, params):
        t = np.array(_toks(63, seed=2))
        full = np.append(t, 7).astype(np.int32)
        lg_full = M.forward(params, jnp.asarray(full), cfg)
        _, kc, vc = M.prefill(params, jnp.asarray(t), cfg)
        c = 96
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, c - 63), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, c - 63), (0, 0)))
        lg_d, _, _ = M.decode_step(params, jnp.int32(7), kc, vc,
                                   jnp.int32(63), cfg)
        np.testing.assert_allclose(np.array(lg_d), np.array(lg_full[-1]),
                                   rtol=1e-4, atol=1e-5)

    def test_multi_step_decode(self, cfg, params):
        t = np.array(_toks(32, seed=3))
        _, kc, vc = M.prefill(params, jnp.asarray(t), cfg)
        c = 48
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, c - 32), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, c - 32), (0, 0)))
        seq = list(t)
        for step in range(4):
            nxt = jnp.int32((7 + step) % tasks.VOCAB)
            lg, kc, vc = M.decode_step(params, nxt, kc, vc,
                                       jnp.int32(32 + step), cfg)
            seq.append(int(nxt))
        lg_full = M.forward(params, jnp.asarray(np.array(seq, np.int32)), cfg)
        np.testing.assert_allclose(np.array(lg), np.array(lg_full[-1]),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_decode_matches_single(self, cfg, params):
        t = np.array(_toks(16, seed=4))
        _, kc, vc = M.prefill(params, jnp.asarray(t), cfg)
        c = 32
        kc1 = jnp.pad(kc, ((0, 0), (0, 0), (0, c - 16), (0, 0)))
        vc1 = jnp.pad(vc, ((0, 0), (0, 0), (0, c - 16), (0, 0)))
        lg1, _, _ = M.decode_step(params, jnp.int32(9), kc1, vc1,
                                  jnp.int32(16), cfg)
        kb = jnp.stack([kc1, kc1], axis=1)
        vb = jnp.stack([vc1, vc1], axis=1)
        lgb, _, _ = M.decode_step_batch(
            params, jnp.array([9, 9], jnp.int32), kb, vb,
            jnp.array([16, 16], jnp.int32), cfg)
        np.testing.assert_allclose(np.array(lgb[0]), np.array(lg1),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.array(lgb[1]), np.array(lg1),
                                   rtol=1e-5, atol=1e-6)


class TestParams:
    def test_flatten_round_trip(self, cfg, params):
        flat = M.flatten_params(params, cfg)
        rebuilt = M.unflatten_params([a for _, a in flat], cfg)
        lg1 = M.forward(params, _toks(16), cfg)
        lg2 = M.forward(rebuilt, _toks(16), cfg)
        np.testing.assert_array_equal(np.array(lg1), np.array(lg2))

    def test_flatten_names_stable(self, cfg, params):
        names = [n for n, _ in M.flatten_params(params, cfg)]
        assert names[0] == "embed" and names[-1] == "ln_f"
        assert names[1] == "layers.0.ln1" and "layers.1.wq" in names


class TestTraining:
    def test_loss_decreases(self, cfg):
        params, hist = M.train(cfg, steps=30, batch=8, length=96,
                               verbose=False, seed=7)
        first = np.mean(hist[:5])
        last = np.mean(hist[-5:])
        assert last < first, (first, last)

    def test_adam_shapes(self, cfg, params):
        opt = M.adam_init(params)
        grads = jax.tree_util.tree_map(jnp.ones_like, params)
        p2, opt2 = M.adam_update(params, grads, opt)
        assert int(opt2["t"]) == 1
        chex_leaves = jax.tree_util.tree_leaves(p2)
        assert all(np.all(np.isfinite(np.array(l))) for l in chex_leaves)


class TestTasks:
    @pytest.mark.parametrize("name", tasks.TASK_NAMES)
    def test_generator_shapes(self, name):
        rng = np.random.default_rng(0)
        toks, mask = tasks.GENERATORS[name](rng, 128)
        assert toks.shape == (128,) and mask.shape == (128,)
        assert toks.min() >= 0 and toks.max() < tasks.VOCAB
        assert mask.sum() > 0

    def test_copy_is_copy(self):
        rng = np.random.default_rng(1)
        toks, mask = tasks.gen_copy(rng, 130)
        # Payload length is randomized; recover it from the SEP position.
        n = int(np.argmax(toks == tasks.SEP)) - 1
        assert 8 <= n <= 64
        np.testing.assert_array_equal(toks[1:1 + n], toks[2 + n:2 + 2 * n])
        # Fixed-n variant still supported (and exactly fills the seq).
        toks2, _ = tasks.gen_copy(rng, 130, n=64)
        np.testing.assert_array_equal(toks2[1:65], toks2[66:130])

    def test_needle_answer_is_val(self):
        rng = np.random.default_rng(2)
        toks, mask = tasks.gen_needle(rng, 128, n_pairs=2)
        # Each queried key must restate the val that followed its needle.
        mrk_positions = np.flatnonzero(toks == tasks.MRK)
        assert len(mrk_positions) == 2
        kv = {int(toks[p + 1]): int(toks[p + 2]) for p in mrk_positions}
        qry_positions = np.flatnonzero(toks == tasks.QRY)
        assert len(qry_positions) == 2
        for qp in qry_positions:
            key, val = int(toks[qp + 1]), int(toks[qp + 2])
            assert kv[key] == val
            # Key occurs exactly twice: at its needle and at its query.
            assert (toks == key).sum() == 2
        # Masked positions are exactly the key positions in the queries,
        # carrying the needle loss weight.
        assert (mask > 0).sum() == 2
        for qp in qry_positions:
            assert mask[qp + 1] == tasks.NEEDLE_WEIGHT

    def test_induction_periodicity(self):
        rng = np.random.default_rng(3)
        toks, _ = tasks.gen_induction(rng, 64)
        # Self-consistent with some period p (position 0 is BOS).
        ok = any(
            all(toks[i] == toks[i - p] for i in range(p + 1, 64))
            for p in range(4, 9)
        )
        assert ok

    def test_batch_mixes_tasks(self):
        rng = np.random.default_rng(4)
        toks, mask = tasks.gen_batch(rng, 16, 96)
        assert toks.shape == (16, 96) and mask.shape == (16, 96)
