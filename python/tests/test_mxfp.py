"""Unit + property tests for the MXFP format primitives."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels import mxfp

E2M1_VALUES = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def arr(xs):
    return jnp.asarray(np.array(xs, np.float32))


# ---------------------------------------------------------------------------
# E2M1 (Algorithm 3)
# ---------------------------------------------------------------------------

class TestE2M1:
    def test_representables_round_trip(self):
        vals = E2M1_VALUES + [-v for v in E2M1_VALUES]
        out = mxfp.decode_e2m1(mxfp.encode_e2m1(arr(vals)))
        np.testing.assert_array_equal(np.array(out), np.array(vals, np.float32))

    def test_codes_are_4bit(self):
        x = arr(np.linspace(-6, 6, 1001))
        codes = np.array(mxfp.encode_e2m1(x))
        assert codes.max() <= 0x0F

    def test_exponent_thresholds(self):
        # Step 4.2: E = sum of indicators at {1, 2, 4}.
        x = arr([0.3, 0.9, 1.0, 1.9, 2.0, 3.9, 4.0, 6.0])
        e = (np.array(mxfp.encode_e2m1(x)) >> 1) & 3
        np.testing.assert_array_equal(e, [0, 0, 1, 1, 2, 2, 3, 3])

    def test_ties_round_to_even_mantissa(self):
        # Paper's example: input 5 must round to 4 (M=0), not 6.
        out = mxfp.decode_e2m1(mxfp.encode_e2m1(arr([5.0, -5.0])))
        np.testing.assert_array_equal(np.array(out), [4.0, -4.0])

    def test_midpoints(self):
        # Strict '>' at midpoints 1.25*2^(E-1): 2.5 -> 2, 2.51 -> 3.
        out = np.array(mxfp.decode_e2m1(mxfp.encode_e2m1(
            arr([2.5, 2.51, 1.25, 1.26, 0.25, 0.26]))))
        np.testing.assert_array_equal(out, [2.0, 3.0, 1.0, 1.5, 0.0, 0.5])

    def test_sign_bit(self):
        codes = np.array(mxfp.encode_e2m1(arr([-1.0, 1.0])))
        assert codes[0] >> 3 == 1 and codes[1] >> 3 == 0

    @settings(max_examples=200, deadline=None)
    @given(st.floats(min_value=-6.0, max_value=6.0, allow_nan=False))
    def test_quantize_within_half_step(self, v):
        """Quantized value is one of the two E2M1 neighbours of v."""
        q = float(mxfp.quantize_e2m1(arr([v]))[0])
        grid = sorted(E2M1_VALUES + [-g for g in E2M1_VALUES])
        lo = max([g for g in grid if g <= v], default=-6.0)
        hi = min([g for g in grid if g >= v], default=6.0)
        assert q in (lo, hi), (v, q, lo, hi)

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.floats(-6, 6, allow_nan=False), min_size=1, max_size=64))
    def test_decode_encode_idempotent(self, vs):
        q1 = mxfp.quantize_e2m1(arr(vs))
        q2 = mxfp.quantize_e2m1(q1)
        np.testing.assert_array_equal(np.array(q1), np.array(q2))


# ---------------------------------------------------------------------------
# FP4 packing (Step 5)
# ---------------------------------------------------------------------------

class TestPacking:
    def test_pack_unpack_round_trip(self):
        codes = jnp.asarray(np.arange(64, dtype=np.uint8) % 16).reshape(4, 16)
        rt = mxfp.unpack_fp4(mxfp.pack_fp4(codes))
        np.testing.assert_array_equal(np.array(rt), np.array(codes))

    def test_high_index_in_high_nibble(self):
        codes = jnp.asarray(np.array([[0x3, 0xA]], np.uint8))
        packed = np.array(mxfp.pack_fp4(codes))
        assert packed[0, 0] == (0xA << 4) | 0x3

    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 16))
    def test_pack_shapes(self, half):
        codes = jnp.asarray(
            np.random.default_rng(0).integers(0, 16, (3, 2 * half)), jnp.uint8)
        packed = mxfp.pack_fp4(codes)
        assert packed.shape == (3, half)


# ---------------------------------------------------------------------------
# E4M3 / E5M2
# ---------------------------------------------------------------------------

class TestFP8:
    def test_e4m3_max_normal(self):
        out = np.array(mxfp.quantize_e4m3(arr([448.0, 1000.0, -1000.0])))
        np.testing.assert_array_equal(out, [448.0, 448.0, -448.0])

    def test_e4m3_code_round_trip_exhaustive(self):
        """All 256 codes except NaN patterns decode->encode stably."""
        codes = np.arange(256, dtype=np.uint8)
        # Exclude NaN patterns S.1111.111.
        codes = codes[(codes & 0x7F) != 0x7F]
        vals = mxfp.decode_e4m3(jnp.asarray(codes))
        rt = mxfp.decode_e4m3(mxfp.encode_e4m3(vals))
        np.testing.assert_array_equal(np.array(rt), np.array(vals))

    def test_e5m2_code_round_trip(self):
        codes = np.arange(256, dtype=np.uint8)
        e = (codes >> 2) & 0x1F
        codes = codes[e != 0x1F]  # exclude inf/NaN exponent
        vals = mxfp.decode_e5m2(jnp.asarray(codes))
        rt = mxfp.decode_e5m2(mxfp.encode_e5m2(vals))
        np.testing.assert_array_equal(np.array(rt), np.array(vals))

    def test_e4m3_subnormals(self):
        step = 2.0 ** -9
        out = np.array(mxfp.quantize_e4m3(arr([step, 3 * step, 0.0])))
        np.testing.assert_allclose(out, [step, 3 * step, 0.0])

    @settings(max_examples=200, deadline=None)
    @given(st.floats(min_value=-448, max_value=448, allow_nan=False))
    def test_e4m3_relative_error_bound(self, v):
        q = float(mxfp.quantize_e4m3(arr([v]))[0])
        if abs(v) >= 2.0 ** -6:  # normal range: rel err <= 2^-4
            assert abs(q - v) <= abs(v) * 2.0 ** -4 + 1e-12
        else:  # subnormal: abs err <= half step
            assert abs(q - v) <= 2.0 ** -10 + 1e-12

    @settings(max_examples=100, deadline=None)
    @given(st.floats(min_value=-5e4, max_value=5e4, allow_nan=False))
    def test_e5m2_monotone(self, v):
        q1 = float(mxfp.quantize_e5m2(arr([v]))[0])
        q2 = float(mxfp.quantize_e5m2(arr([v + abs(v) * 0.1 + 0.1]))[0])
        assert q2 >= q1


# ---------------------------------------------------------------------------
# Shared scales (Steps 3 / 6 / 7)
# ---------------------------------------------------------------------------

class TestScales:
    def test_e8m0_code_range(self):
        amax = arr([1e-38, 1.0, 1e30])
        _, code = mxfp.e8m0_shared_scale(amax, mxfp.E4M3_EMAX)
        c = np.array(code)
        assert c.min() >= 0 and c.max() <= 254

    def test_e8m0_power_of_two(self):
        scale, code = mxfp.e8m0_shared_scale(arr([448.0]), mxfp.E4M3_EMAX)
        # amax 448 -> floor(log2) = 8, minus emax 8 -> 2^0.
        assert float(scale[0]) == 1.0
        assert int(code[0]) == 127

    def test_e8m0_scale_matches_code(self):
        for a in (0.001, 0.5, 3.0, 100.0, 7e4):
            scale, code = mxfp.e8m0_shared_scale(arr([a]), mxfp.E2M1_EMAX)
            assert float(scale[0]) == 2.0 ** (int(code[0]) - 127)

    def test_nvfp4_scale_is_e4m3_value(self):
        amax = arr([3.7, 0.02, 500.0])
        s, code = mxfp.nvfp4_shared_scale(amax)
        dec = mxfp.decode_e4m3(code)
        np.testing.assert_array_equal(np.array(s), np.array(dec))

    def test_nvfp4_scale_never_zero(self):
        s, _ = mxfp.nvfp4_shared_scale(arr([0.0]))
        assert float(s[0]) > 0


# ---------------------------------------------------------------------------
# Block fake-quantization (format zoo)
# ---------------------------------------------------------------------------

class TestBlockQuant:
    @pytest.mark.parametrize("fn", [
        mxfp.fake_quant_mxfp4,
        mxfp.fake_quant_mxfp8,
        mxfp.fake_quant_nvfp4,
    ])
    def test_shape_preserved(self, fn):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)
        assert fn(x).shape == x.shape

    def test_error_ordering_matches_table2(self):
        """MXFP4 error >> NVFP4 error >= MXFP8 error (paper Table 2).

        The gap shows on channel-structured activations (paper Sec. 4 /
        Fig. 1): a few channels carry much larger magnitudes, which a
        coarse power-of-two 32-block scale handles far worse than
        NVFP4's finer 16-block E4M3 scale.
        """
        rng = np.random.default_rng(7)
        chan = 1.0 + 0.5 * np.sin(np.arange(128) * 0.37)
        out_idx = rng.permutation(128)[:8]
        chan[out_idx] *= 8.0
        x = jnp.asarray((rng.normal(size=(64, 128)) * chan).astype(np.float32))
        def rel(y):
            return float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        e4 = rel(mxfp.fake_quant_mxfp4(x))
        env = rel(mxfp.fake_quant_nvfp4(x))
        e8 = rel(mxfp.fake_quant_mxfp8(x))
        assert e4 > 1.15 * env, (e4, env)
        assert env > 2 * e8, (env, e8)

    def test_mxfp8_high_fidelity(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
        q = mxfp.fake_quant_mxfp8(x)
        cos = float(jnp.sum(q * x) / (jnp.linalg.norm(q) * jnp.linalg.norm(x)))
        assert cos > 0.998

    def test_tokenwise_improves_outlier_rows(self):
        rng = np.random.default_rng(5)
        x = rng.normal(size=(32, 64)).astype(np.float32)
        x[7] *= 100.0  # one outlier token
        x = jnp.asarray(x)
        base = mxfp.fake_quant_nvfp4(x, tokenwise=False)
        tok = mxfp.fake_quant_nvfp4(x, tokenwise=True)
        err_b = float(jnp.linalg.norm(base[3] - x[3]))
        err_t = float(jnp.linalg.norm(tok[3] - x[3]))
        assert err_t <= err_b * 1.5  # non-outlier rows not hurt

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 8), st.sampled_from([32, 64, 128]))
    def test_idempotent_all_formats(self, rows, d):
        rng = np.random.default_rng(rows * d)
        x = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
        for fn in (mxfp.fake_quant_mxfp4, mxfp.fake_quant_mxfp8,
                   mxfp.fake_quant_nvfp4):
            q = fn(x)
            q2 = fn(q)
            np.testing.assert_allclose(np.array(q), np.array(q2),
                                       rtol=0, atol=1e-6)
