"""Flash + DMA attention Pallas kernels vs oracles."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels import dma_attention as da
from compile.kernels import flash, quant_fused as qf, ref


def _qkv(l, d, seed=0, lk=None):
    rng = np.random.default_rng(seed)
    lk = lk or l
    q = jnp.asarray(rng.normal(size=(l, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(lk, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(lk, d)).astype(np.float32))
    return q, k, v


class TestFlash:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("l,d,bm,bn", [
        (128, 64, 64, 64), (128, 32, 32, 64), (256, 64, 64, 32),
    ])
    def test_matches_exact(self, causal, l, d, bm, bn):
        q, k, v = _qkv(l, d, seed=l + d + bm)
        o = flash.flash_attention(q, k, v, bm=bm, bn=bn, causal=causal)
        o_ref = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.array(o), np.array(o_ref),
                                   rtol=1e-4, atol=1e-5)

    def test_rectangular_causal(self):
        """Lq < Lk (query block over a longer KV history)."""
        q, k, v = _qkv(64, 64, seed=11, lk=192)
        o = flash.flash_attention(q, k, v, causal=True)
        o_ref = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.array(o), np.array(o_ref),
                                   rtol=1e-4, atol=1e-5)

    def test_mha_wrapper(self):
        rng = np.random.default_rng(0)
        q, k, v = (jnp.asarray(rng.normal(size=(4, 128, 32)).astype(np.float32))
                   for _ in range(3))
        o = flash.flash_attention_mha(q, k, v, bm=64, bn=64)
        for h in range(4):
            o_ref = ref.attention_ref(q[h], k[h], v[h], causal=True)
            np.testing.assert_allclose(np.array(o[h]), np.array(o_ref),
                                       rtol=1e-4, atol=1e-5)


class TestDMAKernel:
    """The kernel must agree with the tile-level oracle computed on its own
    quantized operands — this isolates the Algorithm-1 control flow
    (phases, masks, online softmax) from quantization tie-breaks."""

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("diag,sink", [
        (128, 0), (128, 128), (64, 64), (0, 0), (256, 0), (0, 64),
    ])
    def test_matches_tile_oracle(self, causal, diag, sink):
        q, k, v = _qkv(256, 64, seed=diag + sink + causal)
        qq = qf.dual_quant(q, is_query=True)
        kq = qf.dual_quant(k, is_query=False)
        o = da.dma_attention_quantized(qq, kq, v, bm=64, bn=64, diag=diag,
                                       sink=sink, causal=causal)
        oo = da.dma_oracle_from_quants(qq, kq, v, bm=64, bn=64, diag=diag,
                                       sink=sink, causal=causal)
        np.testing.assert_allclose(np.array(o), np.array(oo),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("bm,bn", [(32, 32), (64, 32), (32, 64)])
    def test_tile_shapes(self, bm, bn):
        q, k, v = _qkv(128, 32, seed=bm * bn)
        qq = qf.dual_quant(q, is_query=True)
        kq = qf.dual_quant(k, is_query=False)
        o = da.dma_attention_quantized(qq, kq, v, bm=bm, bn=bn, diag=64,
                                       sink=32, causal=True)
        oo = da.dma_oracle_from_quants(qq, kq, v, bm=bm, bn=bn, diag=64,
                                       sink=32, causal=True)
        np.testing.assert_allclose(np.array(o), np.array(oo),
                                   rtol=1e-4, atol=1e-5)

    def test_rectangular_causal(self):
        q, k, v = _qkv(64, 64, seed=21, lk=256)
        qq = qf.dual_quant(q, is_query=True)
        kq = qf.dual_quant(k, is_query=False)
        o = da.dma_attention_quantized(qq, kq, v, bm=64, bn=64, diag=128,
                                       sink=64, causal=True)
        oo = da.dma_oracle_from_quants(qq, kq, v, bm=64, bn=64, diag=128,
                                       sink=64, causal=True)
        np.testing.assert_allclose(np.array(o), np.array(oo),
                                   rtol=1e-4, atol=1e-5)

    def test_full_high_equals_mxfp8_attention(self):
        """diag >= L: every tile is high precision.

        Exact check against the tile oracle on the kernel's own quants
        (bit-identical), plus a loose cos-sim check against the
        independent jnp reference quantizer (separately compiled graphs
        can flip 1-ulp rounding ties in S_q, so only similarity holds).
        """
        q, k, v = _qkv(128, 64, seed=31)
        qq = qf.dual_quant(q, is_query=True)
        kq = qf.dual_quant(k, is_query=False)
        o = da.dma_attention_quantized(qq, kq, v, bm=64, bn=64, diag=4096,
                                       sink=0)
        oo = da.dma_oracle_from_quants(qq, kq, v, bm=64, bn=64, diag=4096,
                                       sink=0)
        np.testing.assert_allclose(np.array(o), np.array(oo),
                                   rtol=1e-4, atol=1e-5)
        # Independent-quantizer comparison (MXFP8-only attention).
        ql, qh, _ = ref.dual_quant_ref(q, is_query=True)
        kl, kh, _ = ref.dual_quant_ref(k, is_query=False)
        s = qh @ kh.T
        lq = q.shape[0]
        mask = jnp.arange(lq)[None, :] > jnp.arange(lq)[:, None]
        s = jnp.where(mask, -jnp.inf, s)
        p = jnp.exp2(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        o_ref = np.array(p @ v).ravel()
        o_flat = np.array(o).ravel()
        cos = float(np.dot(o_ref, o_flat)
                    / (np.linalg.norm(o_ref) * np.linalg.norm(o_flat)))
        assert cos > 0.999, cos

    def test_close_to_exact_attention(self):
        """End-to-end losslessness proxy: DMA vs exact, cos > 0.999."""
        q, k, v = _qkv(256, 64, seed=41)
        o = da.dma_attention(q, k, v, bm=64, bn=64, diag=128, sink=64)
        o_ref = ref.attention_ref(q, k, v, causal=True)
        cos = float(jnp.sum(o * o_ref)
                    / (jnp.linalg.norm(o) * jnp.linalg.norm(o_ref)))
        assert cos > 0.998, cos

    def test_diag_reduces_error_vs_pure_low(self):
        """The paper's core claim: the diagonal window recovers accuracy."""
        q, k, v = _qkv(256, 64, seed=51)
        o_ref = ref.attention_ref(q, k, v, causal=True)
        def err(diag, sink):
            o = da.dma_attention(q, k, v, bm=64, bn=64, diag=diag, sink=sink)
            return float(jnp.linalg.norm(o - o_ref))
        e_none = err(0, 0)
        e_dma = err(128, 64)
        assert e_dma < e_none, (e_dma, e_none)

    def test_mha_wrapper(self):
        rng = np.random.default_rng(0)
        q, k, v = (jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
                   for _ in range(3))
        o = da.dma_attention_mha(q, k, v, bm=32, bn=32, diag=64, sink=32)
        assert o.shape == (2, 128, 32)
        for h in range(2):
            o_ref = ref.attention_ref(q[h], k[h], v[h], causal=True)
            cos = float(jnp.sum(o[h] * o_ref)
                        / (jnp.linalg.norm(o[h]) * jnp.linalg.norm(o_ref)))
            assert cos > 0.995

    @settings(max_examples=8, deadline=None)
    @given(
        l=st.sampled_from([64, 128, 192]),
        d=st.sampled_from([32, 64]),
        diag=st.sampled_from([0, 64, 128]),
        sink=st.sampled_from([0, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, l, d, diag, sink, seed):
        """Shape/config sweep: kernel vs tile oracle at bm=bn=32."""
        q, k, v = _qkv(l, d, seed=seed)
        qq = qf.dual_quant(q, is_query=True)
        kq = qf.dual_quant(k, is_query=False)
        o = da.dma_attention_quantized(qq, kq, v, bm=32, bn=32, diag=diag,
                                       sink=sink, causal=True)
        oo = da.dma_oracle_from_quants(qq, kq, v, bm=32, bn=32, diag=diag,
                                       sink=sink, causal=True)
        np.testing.assert_allclose(np.array(o), np.array(oo),
                                   rtol=1e-4, atol=1e-5)


class TestReferenceProperties:
    def test_softmax_rows_sum_to_one(self):
        q, k, _ = _qkv(64, 32, seed=61)
        p = ref.attention_scores_ref(q, k, causal=True)
        np.testing.assert_allclose(np.array(p.sum(axis=-1)),
                                   np.ones(64), rtol=1e-5)

    def test_high_fraction_monotone_in_diag(self):
        fracs = [ref.high_fraction(512, 512, d, 0, 64, 64) for d in
                 (0, 64, 128, 256, 512)]
        assert all(a <= b for a, b in zip(fracs, fracs[1:])), fracs

    def test_high_fraction_table5_band(self):
        """Paper Table 5 normalizes Bithigh% by the FULL LxL matrix (the
        reported 1.15% for diag=128 equals diag/L at L~=11.1k); our ref
        normalizes by the causally-valid half, so the equivalent band is
        2x the full-matrix number at matching L."""
        f = ref.high_fraction(11136, 11136, 128, 128, 64, 64)
        assert 0.02 < f < 0.08, f  # ~2 * 2.30%

    def test_dma_ref_equals_exact_when_formats_disabled(self):
        """With diag covering everything, tiled ref == MXFP8-only ref and
        both stay close to exact attention."""
        q, k, v = _qkv(128, 64, seed=71)
        o1 = ref.dma_attention_tiled_ref(q, k, v, diag=4096, sink=0)
        o2 = ref.attention_ref(q, k, v, causal=True)
        cos = float(jnp.sum(o1 * o2) / (jnp.linalg.norm(o1) * jnp.linalg.norm(o2)))
        assert cos > 0.999
