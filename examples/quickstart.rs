//! Quickstart: the DMA pipeline on a single attention head, no artifacts
//! required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API surface:
//!   1. fused dual-MXFP quantization of Q and K (Algorithm 2),
//!   2. the Diagonal-Tiled Mixed-Precision attention loop (Algorithm 1),
//!   3. accuracy comparison against exact attention and against the
//!      pure-low-precision ablation.

use dma::attention::dma::{dma_attention_quantized, fixed_format_attention};
use dma::attention::{flash, reference, TileConfig};
use dma::metrics;
use dma::mxfp::block::{Format, Granularity};
use dma::mxfp::fused::dual_quant;
use dma::tensor::{randn, Tensor};
use dma::util::rng::{channelwise_qk, Rng};

fn main() {
    let (l, d) = (512usize, 64usize);
    println!("== DMA quickstart: one attention head, L={l}, D={d} ==\n");

    // Channel-structured Q/K like real LLM activations (paper Sec. 4).
    let mut rng = Rng::new(42);
    let q = Tensor::new(vec![l, d], channelwise_qk(&mut rng, l, d, 6, 8.0));
    let k = Tensor::new(vec![l, d], channelwise_qk(&mut rng, l, d, 6, 8.0));
    let v = randn(vec![l, d], 3);

    // 1. Fused dual quantization (both precisions in one pass).
    let qq = dual_quant(&q.data, l, d, true, Granularity::PerToken);
    let kq = dual_quant(&k.data, l, d, false, Granularity::PerToken);
    println!(
        "quantized Q: {} bytes ({}x smaller than f32)",
        qq.quantized_bytes(),
        (l * d * 4) as f64 / qq.quantized_bytes() as f64
    );

    // 2. DMA attention with the paper's default 128/128 window.
    let cfg = TileConfig { bm: 64, bn: 64, diag: 128, sink: 128, causal: true };
    println!(
        "window: diag={} sink={} -> {:.2}% of valid area in high precision",
        cfg.diag,
        cfg.sink,
        100.0 * cfg.high_fraction(l, l)
    );
    let o_dma = dma_attention_quantized(&qq, &kq, &v, &cfg);

    // 3. Compare against exact attention and ablations.
    let o_exact = reference::attention(&q, &k, &v, true);
    let o_flash = flash::flash_attention(&q, &k, &v, &cfg);
    let all_low = TileConfig { diag: 0, sink: 0, ..cfg };
    let o_low = dma_attention_quantized(&qq, &kq, &v, &all_low);
    let o_mxfp4 = fixed_format_attention(&q, &k, &v, Format::Mxfp4, false, &cfg);

    println!("\n{:<28} {:>9} {:>9}", "variant", "cos sim", "rmse");
    for (name, o) in [
        ("flash (exact, tiled)", &o_flash),
        ("DMA 128/128 (ours)", &o_dma),
        ("pure NVFP4 (diag=0)", &o_low),
        ("pure MXFP4 baseline", &o_mxfp4),
    ] {
        println!(
            "{:<28} {:>9.4} {:>9.5}",
            name,
            metrics::cos_sim(&o_exact.data, &o.data),
            metrics::rmse(&o_exact.data, &o.data)
        );
    }

    let c_dma = metrics::cos_sim(&o_exact.data, &o_dma.data);
    let c_low = metrics::cos_sim(&o_exact.data, &o_low.data);
    println!(
        "\nThe diagonal window recovers {:.4} -> {:.4} cosine similarity \
         while keeping {:.1}% of tiles in 4-bit.",
        c_low,
        c_dma,
        100.0 * (1.0 - cfg.high_fraction(l, l))
    );
}
