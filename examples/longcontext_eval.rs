//! Long-context evaluation: native vs DMA attention on the trained model
//! (the interactive companion to `cargo bench --bench table3_longbench`).
//!
//! Shows per-example needle retrievals so the losslessness claim is
//! inspectable, not just a number.
//!
//! ```bash
//! make artifacts && cargo run --release --example longcontext_eval
//! cargo run --release --example longcontext_eval -- --host-backend
//! ```

use dma::config::{MetaConfig, TokenIds};
use dma::eval;
use dma::model::argmax;
use dma::runtime::host::HostBackend;
use dma::runtime::pjrt::PjrtBackend;
use dma::runtime::ModelBackend;
use dma::util::cli::Args;
use dma::util::rng::Rng;

fn main() {
    let args = Args::parse(&["host-backend"]);
    let artifacts = args.get_or("artifacts", "artifacts");
    let host = args.flag("host-backend");

    let (mut backend, ids, shape): (Box<dyn ModelBackend>, TokenIds, (usize, usize)) =
        if host {
            (
                Box::new(HostBackend::for_tests()),
                TokenIds { pad: 0, bos: 1, sep: 2, qry: 3, mrk: 4, eos: 5,
                           payload_start: 6, vocab: 64 },
                (4, 48),
            )
        } else {
            let meta = MetaConfig::load(&artifacts).expect("run `make artifacts`");
            let ids = meta.tokens;
            let shape = *meta.eval_shapes.last().expect("eval shapes");
            (Box::new(PjrtBackend::new(meta).expect("pjrt")), ids, shape)
        };
    let (b, l) = shape;

    println!("== needle-in-a-haystack, batch={b} length={l}, backend={} ==\n",
             backend.name());
    let mut rng = Rng::new(args.usize_or("seed", 13) as u64);
    let examples: Vec<eval::Example> =
        (0..b).map(|_| eval::gen_needle(&mut rng, &ids, l)).collect();

    let vocab = backend.vocab();
    let mut flat = Vec::new();
    for e in &examples {
        flat.extend_from_slice(&e.tokens);
    }
    let lg_native = backend.eval_logits(&flat, b, l, false).expect("native");
    let lg_dma = backend.eval_logits(&flat, b, l, true).expect("dma");

    let mut ok = [0usize; 2];
    let mut total = 0usize;
    for (bi, e) in examples.iter().enumerate() {
        for t in 0..l - 1 {
            if e.mask[t] == 0.0 {
                continue;
            }
            total += 1;
            let expect = e.tokens[t + 1];
            let p_n = argmax(&lg_native[(bi * l + t) * vocab..(bi * l + t + 1) * vocab]);
            let p_d = argmax(&lg_dma[(bi * l + t) * vocab..(bi * l + t + 1) * vocab]);
            ok[0] += (p_n == expect) as usize;
            ok[1] += (p_d == expect) as usize;
            println!(
                "  ex{bi:<2} key={:<3} expect val={:<3} native={:<3}{} dma={:<3}{}",
                e.tokens[t],
                expect,
                p_n,
                if p_n == expect { " ok" } else { " XX" },
                p_d,
                if p_d == expect { " ok" } else { " XX" },
            );
        }
    }
    println!(
        "\nretrieval accuracy: native {}/{} = {:.2}  |  DMA {}/{} = {:.2}",
        ok[0], total, ok[0] as f64 / total as f64,
        ok[1], total, ok[1] as f64 / total as f64,
    );

    // Full suite summary.
    println!("\nfull suite (all tasks):");
    let shapes = vec![shape];
    let rows = eval::run_suite(backend.as_mut(), &ids, &shapes, 29).expect("suite");
    for r in &rows {
        println!("  {:<16} native={:.3} dma={:.3}", r.task, r.native, r.dma);
    }
    println!("\nlongcontext_eval OK");
}
