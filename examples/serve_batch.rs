//! End-to-end serving driver (the EXPERIMENTS.md E2E run).
//!
//! Loads the small build-time-trained model through the PJRT runtime,
//! spins up the full coordinator (engine worker + router), submits a
//! batch of long-context requests (copy / needle / induction prompts),
//! and reports latency/throughput. Python is never on this path.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch
//! cargo run --release --example serve_batch -- --host-backend   # no artifacts
//! cargo run --release --example serve_batch -- --requests 32 --workers 1
//! ```

use dma::config::{EngineConfig, MetaConfig, TokenIds};
use dma::coordinator::engine::EngineHandle;
use dma::coordinator::router::{Policy, Router};
use dma::coordinator::Request;
use dma::runtime::host::HostBackend;
use dma::runtime::pjrt::PjrtBackend;
use dma::runtime::ModelBackend;
use dma::util::cli::Args;
use dma::util::rng::Rng;
use std::time::Instant;

fn main() {
    let args = Args::parse(&["host-backend", "native"]);
    let artifacts = args.get_or("artifacts", "artifacts");
    let n_requests = args.usize_or("requests", 24);
    let workers = args.usize_or("workers", 1);
    let max_new = args.usize_or("max-new-tokens", 16);
    let host = args.flag("host-backend");
    let dma_mode = !args.flag("native");

    let (ids, prompt_lens): (TokenIds, Vec<usize>) = if host {
        (
            TokenIds { pad: 0, bos: 1, sep: 2, qry: 3, mrk: 4, eos: 5,
                       payload_start: 6, vocab: 64 },
            vec![16, 24, 32],
        )
    } else {
        let meta = MetaConfig::load(&artifacts).expect("run `make artifacts` first");
        (meta.tokens, vec![48, 96, 200])
    };

    // Long-context prompts from the three task families.
    let mut rng = Rng::new(args.usize_or("seed", 1) as u64);
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| {
            let l = *rng.choose(&prompt_lens);
            let task = dma::eval::TASKS[i % dma::eval::TASKS.len()];
            let ex = dma::eval::generate(task, &mut rng, &ids, l);
            Request {
                id: i as u64,
                tokens: ex.tokens,
                max_new_tokens: max_new,
                dma: dma_mode,
            }
        })
        .collect();
    let total_prompt_tokens: usize = requests.iter().map(|r| r.tokens.len()).sum();

    println!(
        "== serve_batch: {n_requests} requests, {workers} worker(s), \
         attention={} backend={} ==",
        if dma_mode { "dma" } else { "native" },
        if host { "host-cpu" } else { "pjrt-cpu" },
    );

    let cfg = EngineConfig {
        artifact_dir: artifacts.clone().into(),
        max_new_tokens: max_new,
        ..Default::default()
    };
    let handles: Vec<EngineHandle> = (0..workers)
        .map(|_| {
            let a = artifacts.clone();
            let c = cfg.clone();
            EngineHandle::spawn(
                move || -> dma::Result<Box<dyn ModelBackend>> {
                    if host {
                        Ok(Box::new(HostBackend::for_tests()))
                    } else {
                        Ok(Box::new(PjrtBackend::new(MetaConfig::load(&a)?)?))
                    }
                },
                c,
                ids.eos,
            )
        })
        .collect();
    let router = Router::new(handles, Policy::LeastLoaded);

    let t0 = Instant::now();
    for r in requests {
        router.submit(r).unwrap();
    }
    let mut responses =
        router.collect_responses(n_requests, std::time::Duration::from_secs(900));
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), n_requests, "lost responses");
    responses.sort_by_key(|r| r.id);

    let gen_tokens: usize = responses.iter().map(|r| r.output.len()).sum();
    let mut prefill: Vec<f64> = responses.iter().map(|r| r.prefill_ms).collect();
    let mut e2e: Vec<f64> = responses
        .iter()
        .map(|r| r.queue_ms + r.prefill_ms + r.decode_ms)
        .collect();
    prefill.sort_by(|a, b| a.partial_cmp(b).unwrap());
    e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |v: &[f64], p: f64| v[((v.len() - 1) as f64 * p) as usize];

    println!("\nresults:");
    println!("  wall time            : {wall:.2} s");
    println!("  prompt tokens        : {total_prompt_tokens}");
    println!("  generated tokens     : {gen_tokens}");
    println!(
        "  throughput           : {:.1} tok/s total ({:.1} generated tok/s)",
        (total_prompt_tokens + gen_tokens) as f64 / wall,
        gen_tokens as f64 / wall
    );
    println!(
        "  prefill latency (ms) : p50 {:.1}  p90 {:.1}",
        pct(&prefill, 0.5),
        pct(&prefill, 0.9)
    );
    println!(
        "  e2e latency (ms)     : p50 {:.1}  p90 {:.1}  max {:.1}",
        pct(&e2e, 0.5),
        pct(&e2e, 0.9),
        pct(&e2e, 1.0)
    );
    let finishes: Vec<&str> = responses.iter().map(|r| r.finish.as_str()).collect();
    let eos = finishes.iter().filter(|f| **f == "eos").count();
    let len = finishes.iter().filter(|f| **f == "length").count();
    println!("  finish reasons       : eos={eos} length={len} other={}",
             n_requests - eos - len);
    assert!(responses.iter().all(|r| !r.output.is_empty()));
    println!("\nserve_batch OK");

    router.shutdown();
}
