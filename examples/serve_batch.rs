//! End-to-end serving driver (the EXPERIMENTS.md E2E run).
//!
//! Loads the small build-time-trained model through the PJRT runtime
//! (or the pure-Rust host backend with `--host-backend` — no artifacts
//! or `pjrt` feature needed), spins up the full coordinator (engine
//! workers + router), submits a batch of long-context requests (copy /
//! needle / induction prompts), and reports latency/throughput — then
//! demonstrates the v2 event API: a streamed request printed token by
//! token with its TTFT, and a long request cancelled mid-generation.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example serve_batch
//! cargo run --release --example serve_batch -- --host-backend   # no artifacts
//! cargo run --release --example serve_batch -- --host-backend --requests 32
//! ```

use dma::config::{EngineConfig, TokenIds};
use dma::coordinator::engine::EngineHandle;
use dma::coordinator::router::{Policy, Router};
use dma::coordinator::{EngineEvent, Request, SamplingParams};
use dma::runtime::host::HostBackend;
use dma::runtime::ModelBackend;
use dma::util::cli::Args;
use dma::util::rng::Rng;
use std::time::{Duration, Instant};

fn make_backend(artifacts: &str, host: bool) -> dma::Result<Box<dyn ModelBackend>> {
    if host {
        return Ok(Box::new(HostBackend::for_tests()));
    }
    pjrt_backend(artifacts)
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts: &str) -> dma::Result<Box<dyn ModelBackend>> {
    let meta = dma::config::MetaConfig::load(artifacts)?;
    Ok(Box::new(dma::runtime::pjrt::PjrtBackend::new(meta)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts: &str) -> dma::Result<Box<dyn ModelBackend>> {
    anyhow::bail!(
        "built without the `pjrt` feature; rebuild with --features pjrt \
         or pass --host-backend"
    )
}

#[cfg(feature = "pjrt")]
fn artifact_ids(artifacts: &str) -> (TokenIds, Vec<usize>) {
    let meta =
        dma::config::MetaConfig::load(artifacts).expect("run `make artifacts` first");
    (meta.tokens, vec![48, 96, 200])
}

#[cfg(not(feature = "pjrt"))]
fn artifact_ids(_artifacts: &str) -> (TokenIds, Vec<usize>) {
    eprintln!(
        "built without the `pjrt` feature; pass --host-backend or rebuild \
         with --features pjrt"
    );
    std::process::exit(2)
}

fn main() {
    let args = Args::parse(&["host-backend", "native"]);
    let artifacts = args.get_or("artifacts", "artifacts");
    let n_requests = args.usize_or("requests", 24);
    let workers = args.usize_or("workers", 1);
    let max_new = args.usize_or("max-new-tokens", 16);
    let host = args.flag("host-backend");
    let dma_mode = !args.flag("native");

    let (ids, prompt_lens): (TokenIds, Vec<usize>) = if host {
        (
            TokenIds { pad: 0, bos: 1, sep: 2, qry: 3, mrk: 4, eos: 5,
                       payload_start: 6, vocab: 64 },
            vec![16, 24, 32],
        )
    } else {
        artifact_ids(&artifacts)
    };

    // Long-context prompts from the three task families.
    let mut rng = Rng::new(args.usize_or("seed", 1) as u64);
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| {
            let l = *rng.choose(&prompt_lens);
            let task = dma::eval::TASKS[i % dma::eval::TASKS.len()];
            let ex = dma::eval::generate(task, &mut rng, &ids, l);
            Request {
                id: i as u64,
                tokens: ex.tokens,
                max_new_tokens: max_new,
                dma: dma_mode,
                ..Default::default()
            }
        })
        .collect();
    let total_prompt_tokens: usize = requests.iter().map(|r| r.tokens.len()).sum();

    println!(
        "== serve_batch: {n_requests} requests, {workers} worker(s), \
         attention={} backend={} ==",
        if dma_mode { "dma" } else { "native" },
        if host { "host-cpu" } else { "pjrt-cpu" },
    );

    let cfg = EngineConfig {
        artifact_dir: artifacts.clone().into(),
        max_new_tokens: max_new.max(64),
        // One decode step per scheduler iteration: control messages are
        // drained between steps, so the cancellation demo below has ~60
        // steps of margin instead of ~7 (decode batching is unaffected).
        decode_slice: 1,
        ..Default::default()
    };
    let handles: Vec<EngineHandle> = (0..workers)
        .map(|_| {
            let a = artifacts.clone();
            let c = cfg.clone();
            EngineHandle::spawn(move || make_backend(&a, host), c, ids.eos)
        })
        .collect();
    let router = Router::new(handles, Policy::LeastLoaded);

    let t0 = Instant::now();
    for r in requests {
        router.submit(r).unwrap();
    }
    let mut responses = router.collect_responses(n_requests, Duration::from_secs(900));
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), n_requests, "lost responses");
    responses.sort_by_key(|r| r.id);

    let gen_tokens: usize = responses.iter().map(|r| r.output.len()).sum();
    let mut prefill: Vec<f64> = responses.iter().map(|r| r.prefill_ms).collect();
    let mut ttft: Vec<f64> = responses.iter().map(|r| r.ttft_ms).collect();
    let mut e2e: Vec<f64> = responses
        .iter()
        .map(|r| r.queue_ms + r.prefill_ms + r.decode_ms)
        .collect();
    prefill.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |v: &[f64], p: f64| v[((v.len() - 1) as f64 * p) as usize];

    println!("\nresults:");
    println!("  wall time            : {wall:.2} s");
    println!("  prompt tokens        : {total_prompt_tokens}");
    println!("  generated tokens     : {gen_tokens}");
    println!(
        "  throughput           : {:.1} tok/s total ({:.1} generated tok/s)",
        (total_prompt_tokens + gen_tokens) as f64 / wall,
        gen_tokens as f64 / wall
    );
    println!(
        "  prefill latency (ms) : p50 {:.1}  p90 {:.1}",
        pct(&prefill, 0.5),
        pct(&prefill, 0.9)
    );
    println!(
        "  ttft (ms)            : p50 {:.1}  p90 {:.1}",
        pct(&ttft, 0.5),
        pct(&ttft, 0.9)
    );
    println!(
        "  e2e latency (ms)     : p50 {:.1}  p90 {:.1}  max {:.1}",
        pct(&e2e, 0.5),
        pct(&e2e, 0.9),
        pct(&e2e, 1.0)
    );
    let finishes: Vec<&str> = responses.iter().map(|r| r.finish.as_str()).collect();
    let eos = finishes.iter().filter(|f| **f == "eos").count();
    let len = finishes.iter().filter(|f| **f == "length").count();
    println!("  finish reasons       : eos={eos} length={len} other={}",
             n_requests - eos - len);
    assert!(responses.iter().all(|r| !r.output.is_empty()));

    // ------------------------------------------------------------------
    // Streaming demo: consume one request's event stream token by token.
    // ------------------------------------------------------------------
    println!("\n== streaming (one request, seeded sampling) ==");
    let prompt: Vec<i32> = (0..16).map(|i| ((i * 7) % 50) as i32 + 6).collect();
    let submit_at = Instant::now();
    router
        .submit(Request {
            id: 1_000,
            tokens: prompt.clone(),
            max_new_tokens: 12,
            dma: dma_mode,
            sampling: SamplingParams {
                temperature: 0.8,
                seed: 7,
                ignore_eos: true,
                ..Default::default()
            },
        })
        .unwrap();
    let mut first_token_ms = None;
    'stream: loop {
        for ev in router.poll_events(16) {
            match ev {
                EngineEvent::Started { queue_ms, .. } => {
                    println!("  started (queued {queue_ms:.2} ms)");
                }
                EngineEvent::Token { token, index, .. } => {
                    if index == 0 {
                        first_token_ms =
                            Some(submit_at.elapsed().as_secs_f64() * 1e3);
                    }
                    println!("  token[{index}] = {token}");
                }
                EngineEvent::Finished(r) => {
                    println!(
                        "  finished: {} tokens, finish={}, engine ttft {:.2} ms, \
                         client ttft {:.2} ms",
                        r.output.len(),
                        r.finish.as_str(),
                        r.ttft_ms,
                        first_token_ms.unwrap_or(0.0)
                    );
                    break 'stream;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    // ------------------------------------------------------------------
    // Cancellation demo: abandon a long generation at its first token.
    // The budget (64 tokens ≈ 8 scheduler steps) leaves the cancel many
    // decode steps of margin to land mid-flight.
    // ------------------------------------------------------------------
    println!("\n== cancellation (long request, cancelled at the first token) ==");
    router
        .submit(Request {
            id: 1_001,
            tokens: prompt,
            max_new_tokens: 64,
            dma: dma_mode,
            sampling: SamplingParams { ignore_eos: true, ..Default::default() },
        })
        .unwrap();
    let mut cancelled = false;
    'cancel: loop {
        for ev in router.poll_events(16) {
            match ev {
                EngineEvent::Token { index, .. } if !cancelled => {
                    println!("  token[{index}] seen -> cancel");
                    router.cancel(1_001).unwrap();
                    cancelled = true;
                }
                EngineEvent::Finished(r) => {
                    println!(
                        "  finished: finish={}, {} of 64 tokens generated",
                        r.finish.as_str(),
                        r.output.len()
                    );
                    assert_eq!(r.finish.as_str(), "cancelled");
                    assert!(r.output.len() < 64);
                    break 'cancel;
                }
                _ => {}
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    // ------------------------------------------------------------------
    // Parallel sampling demo: one prompt prefilled once, three
    // candidates forked copy-on-write, finalists ranked by cumulative
    // logprob engine-side.
    // ------------------------------------------------------------------
    println!("\n== parallel sampling (n=3 candidates over one prompt prefill) ==");
    let prompt: Vec<i32> = (0..16).map(|i| ((i * 11) % 50) as i32 + 6).collect();
    router
        .submit(Request {
            id: 1_002,
            tokens: prompt,
            max_new_tokens: 8,
            dma: dma_mode,
            sampling: SamplingParams {
                temperature: 0.8,
                seed: 21,
                ignore_eos: true,
                n: 3,
                ..Default::default()
            },
        })
        .unwrap();
    let mut streamed = [0usize; 3];
    'group: loop {
        for ev in router.poll_events(32) {
            match ev {
                EngineEvent::Token { candidate, .. } => {
                    streamed[candidate] += 1;
                }
                EngineEvent::Finished(r) => {
                    for c in &r.candidates {
                        println!(
                            "  candidate {}: {} tokens, finish={}, cum_logprob {:.3}",
                            c.candidate,
                            c.output.len(),
                            c.finish.as_str(),
                            c.cum_logprob
                        );
                    }
                    assert_eq!(r.candidates.len(), 3);
                    assert_eq!(r.output, r.candidates[0].output, "best-first");
                    break 'group;
                }
                _ => {}
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(streamed.iter().all(|&n| n > 0), "every candidate streamed: {streamed:?}");
    println!("  per-candidate token events: {streamed:?}");

    println!("\nserve_batch OK");
    router.shutdown();
}
