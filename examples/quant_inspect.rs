//! Inspect the MXFP format zoo on a sample tensor: codes, scales,
//! reconstruction error per format — a bit-level teaching tool.
//!
//! ```bash
//! cargo run --release --example quant_inspect [-- --rows 4 --d 32]
//! ```

use dma::metrics;
use dma::mxfp::block::{fake_quant, fake_quant_scaled, Format, Granularity};
use dma::mxfp::fused::dual_quant;
use dma::mxfp::{e2m1, fp8, pack};
use dma::util::cli::Args;
use dma::util::rng::Rng;

fn main() {
    let args = Args::parse(&[]);
    let rows = args.usize_or("rows", 4);
    let d = args.usize_or("d", 32);
    let mut rng = Rng::new(args.usize_or("seed", 9) as u64);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32 * 2.0).collect();

    println!("== E2M1 grid (Algorithm 3) ==");
    println!("representable magnitudes: {:?}", e2m1::E2M1_GRID);
    for v in [0.2f32, 0.7, 1.3, 2.4, 5.0, 7.0] {
        let code = e2m1::encode(v.clamp(-6.0, 6.0));
        println!(
            "  {v:>5} -> code {code:#06b} -> {}  (paper tie rule: 5 -> 4)",
            e2m1::decode(code)
        );
    }

    println!("\n== E4M3 samples ==");
    for v in [0.001f32, 0.37, 17.3, 448.0, 500.0] {
        let code = fp8::encode_e4m3(v);
        println!("  {v:>8} -> {code:#010b} -> {}", fp8::decode_e4m3(code));
    }

    println!("\n== Fused dual quantization of a [{rows}, {d}] tensor ==");
    let q = dual_quant(&x, rows, d, false, Granularity::PerToken);
    println!("  packed FP4 bytes : {:?}...", &q.packed_fp4[..8.min(q.packed_fp4.len())]);
    println!("  NVFP4 scales(E4M3): {:?}", &q.s4_codes[..d / 16]);
    println!("  MXFP8 scales(E8M0): {:?}", &q.s8_codes[..d / 32]);
    println!("  S_q per token     : {:?}", &q.sq[..rows.min(4)]);
    let unpacked = pack::unpack(&q.packed_fp4[..d / 2]);
    println!("  row0 FP4 codes    : {:?}...", &unpacked[..8]);

    let mut low = vec![0f32; rows * d];
    let mut high = vec![0f32; rows * d];
    q.dequant_low(&mut low);
    q.dequant_high(&mut high);

    println!("\n== Reconstruction error per format ==");
    println!("{:<24} {:>9} {:>9}", "format", "cos sim", "rmse");
    let show = |name: &str, y: &[f32]| {
        println!(
            "{:<24} {:>9.4} {:>9.5}",
            name,
            metrics::cos_sim(&x, y),
            metrics::rmse(&x, y)
        );
    };
    show("MXFP4  (E2M1+E8M0/32)", &fake_quant(&x, rows, d, Format::Mxfp4));
    show("MXFP8  (E4M3+E8M0/32)", &fake_quant(&x, rows, d, Format::Mxfp8E4m3));
    show("NVFP4  (E2M1+E4M3/16)", &fake_quant(&x, rows, d, Format::Nvfp4));
    show(
        "NVFP4+ (tokenwise S_q)",
        &fake_quant_scaled(&x, rows, d, Format::Nvfp4, Granularity::PerToken),
    );
    show("dual: low copy (NVFP4)", &low);
    show("dual: high copy(MXFP8)", &high);
}
