//! Synthetic LongBench harness (Table 3 proxy).
//!
//! Mirrors `python/compile/tasks.py`: the same three long-context task
//! families over the same token conventions, generated in Rust and
//! scored by masked-position greedy accuracy through a
//! [`ModelBackend`]'s eval graphs. The paper's claim is *relative*
//! (DMA attention matches native attention on the same model); the
//! harness reports both columns side by side.

use crate::config::TokenIds;
use crate::runtime::ModelBackend;
use crate::util::rng::Rng;

pub const TASKS: [&str; 3] = ["copy", "needle", "induction"];

/// One generated example: tokens plus a 0/1 score mask over *targets*
/// (mask[t] == 1 means position t's target tokens[t+1] is scored).
pub struct Example {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
}

fn payload(rng: &mut Rng, ids: &TokenIds) -> i32 {
    rng.int_in(ids.payload_start as i64, ids.vocab as i64) as i32
}

pub fn gen_copy(rng: &mut Rng, ids: &TokenIds, length: usize) -> Example {
    let n = (length - 2) / 2;
    let w: Vec<i32> = (0..n).map(|_| payload(rng, ids)).collect();
    let mut tokens = vec![ids.pad; length];
    tokens[0] = ids.bos;
    tokens[1..1 + n].copy_from_slice(&w);
    tokens[1 + n] = ids.sep;
    tokens[2 + n..2 + 2 * n].copy_from_slice(&w);
    let mut mask = vec![0f32; length];
    for m in mask.iter_mut().take(1 + 2 * n).skip(1 + n) {
        *m = 1.0;
    }
    Example { tokens, mask }
}

pub fn gen_needle(rng: &mut Rng, ids: &TokenIds, length: usize) -> Example {
    let mut tokens: Vec<i32> = (0..length).map(|_| payload(rng, ids)).collect();
    tokens[0] = ids.bos;
    let key = payload(rng, ids);
    let val = payload(rng, ids);
    let pos = rng.int_in(2, (length as i64 / 3).max(3)) as usize;
    tokens[pos] = ids.mrk;
    tokens[pos + 1] = key;
    tokens[pos + 2] = val;
    // De-duplicate accidental key occurrences (mirrors tasks.py).
    let replacement = ids.payload_start
        + (key - ids.payload_start + 1) % (ids.vocab - ids.payload_start);
    for (i, t) in tokens.iter_mut().enumerate() {
        if *t == key && i != pos + 1 {
            *t = replacement;
        }
    }
    tokens[length - 3] = ids.qry;
    tokens[length - 2] = key;
    tokens[length - 1] = val;
    let mut mask = vec![0f32; length];
    mask[length - 2] = 1.0;
    Example { tokens, mask }
}

pub fn gen_induction(rng: &mut Rng, ids: &TokenIds, length: usize) -> Example {
    let period = rng.int_in(4, 9) as usize;
    let motif: Vec<i32> = (0..period).map(|_| payload(rng, ids)).collect();
    let mut tokens = vec![0i32; length];
    for (i, t) in tokens.iter_mut().enumerate() {
        *t = motif[i % period];
    }
    tokens[0] = ids.bos;
    let mut mask = vec![0f32; length];
    for m in mask.iter_mut().take(length - 1).skip(period) {
        *m = 1.0;
    }
    Example { tokens, mask }
}

pub fn generate(task: &str, rng: &mut Rng, ids: &TokenIds, length: usize) -> Example {
    match task {
        "copy" => gen_copy(rng, ids, length),
        "needle" => gen_needle(rng, ids, length),
        "induction" => gen_induction(rng, ids, length),
        _ => panic!("unknown task {task}"),
    }
}

/// Score one batch of examples through the backend: fraction of masked
/// targets predicted correctly by greedy argmax.
pub fn score_batch(
    backend: &mut dyn ModelBackend,
    examples: &[Example],
    length: usize,
    dma: bool,
) -> crate::Result<f64> {
    let b = examples.len();
    let vocab = backend.vocab();
    let mut tokens = Vec::with_capacity(b * length);
    for e in examples {
        anyhow::ensure!(e.tokens.len() == length, "length mismatch");
        tokens.extend_from_slice(&e.tokens);
    }
    let logits = backend.eval_logits(&tokens, b, length, dma)?;
    let mut correct = 0usize;
    let mut total = 0usize;
    for (bi, e) in examples.iter().enumerate() {
        for t in 0..length - 1 {
            if e.mask[t] > 0.0 {
                let row = &logits[(bi * length + t) * vocab..(bi * length + t + 1) * vocab];
                let pred = crate::model::argmax(row);
                total += 1;
                if pred == e.tokens[t + 1] {
                    correct += 1;
                }
            }
        }
    }
    Ok(if total == 0 { 0.0 } else { correct as f64 / total as f64 })
}

/// Greedy continuation through a backend's serving path: prefill the
/// prompt, then argmax-decode up to `max_new` tokens. This is the
/// reference non-speculative greedy stream — the speculative bench
/// ([`crate::spec`]) diffs its engine output against this loop, and it
/// doubles as a harness entry point for qualitative continuation checks
/// (feed it a [`gen_induction`] prefix and the model should extend the
/// motif). Uses the f32 decode path; `dma` selects the attention flavor.
pub fn greedy_continuation(
    backend: &mut dyn ModelBackend,
    prompt: &[i32],
    max_new: usize,
    dma: bool,
) -> crate::Result<Vec<i32>> {
    anyhow::ensure!(!prompt.is_empty(), "greedy_continuation: empty prompt");
    let vocab = backend.vocab();
    // Decode step i appends emitted token i to the cache; the final
    // emitted token never enters it, hence the +1.
    let cap = backend.cache_len().saturating_sub(prompt.len()) + 1;
    let n = max_new.min(cap);
    let pre = backend.prefill(prompt, dma, None)?;
    let mut kv = pre.kv;
    let mut next = crate::model::argmax(&pre.last_logits[..vocab]);
    let mut out = Vec::with_capacity(n);
    for step in 0..n {
        out.push(next);
        if step + 1 == n {
            break;
        }
        let logits = backend.decode(&[next], &mut [Some(&mut kv)])?;
        next = crate::model::argmax(&logits[..vocab]);
    }
    Ok(out)
}

/// A Table-3 row: task name + native/DMA scores.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub task: String,
    pub native: f64,
    pub dma: f64,
}

/// Run the full suite at the given (batch, length) shapes.
pub fn run_suite(
    backend: &mut dyn ModelBackend,
    ids: &TokenIds,
    shapes: &[(usize, usize)],
    seed: u64,
) -> crate::Result<Vec<EvalRow>> {
    let mut rows = Vec::new();
    for task in TASKS {
        for &(b, l) in shapes {
            let mut rng = Rng::new(seed ^ (l as u64) << 8);
            let examples: Vec<Example> =
                (0..b).map(|_| generate(task, &mut rng, ids, l)).collect();
            let native = score_batch(backend, &examples, l, false)?;
            let dma = score_batch(backend, &examples, l, true)?;
            rows.push(EvalRow { task: format!("{task}_{l}"), native, dma });
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids() -> TokenIds {
        TokenIds { pad: 0, bos: 1, sep: 2, qry: 3, mrk: 4, eos: 5,
                   payload_start: 6, vocab: 64 }
    }

    #[test]
    fn copy_structure() {
        let mut rng = Rng::new(1);
        let e = gen_copy(&mut rng, &ids(), 66);
        let n = 32;
        assert_eq!(e.tokens[0], 1);
        assert_eq!(e.tokens[1 + n], 2);
        assert_eq!(&e.tokens[1..1 + n], &e.tokens[2 + n..2 + 2 * n]);
        assert!(e.mask.iter().sum::<f32>() > 0.0);
    }

    #[test]
    fn needle_key_unique_and_answer_correct() {
        let mut rng = Rng::new(2);
        let tid = ids();
        let e = gen_needle(&mut rng, &tid, 96);
        let l = 96;
        assert_eq!(e.tokens[l - 3], tid.qry);
        let mrk_pos = e.tokens.iter().position(|&t| t == tid.mrk).unwrap();
        let key = e.tokens[mrk_pos + 1];
        let val = e.tokens[mrk_pos + 2];
        assert_eq!(e.tokens[l - 2], key);
        assert_eq!(e.tokens[l - 1], val);
        assert_eq!(e.tokens.iter().filter(|&&t| t == key).count(), 2);
        assert_eq!(e.mask[l - 2], 1.0);
    }

    #[test]
    fn induction_is_periodic() {
        let mut rng = Rng::new(3);
        let e = gen_induction(&mut rng, &ids(), 64);
        let ok = (4..9).any(|p| (p..64).all(|i| i < p + 1 || e.tokens[i] == e.tokens[i - p] || i - p == 0));
        assert!(ok);
    }

    #[test]
    fn tokens_in_range() {
        let mut rng = Rng::new(4);
        let tid = ids();
        for task in TASKS {
            let e = generate(task, &mut rng, &tid, 96);
            assert!(e.tokens.iter().all(|&t| (0..64).contains(&t)), "{task}");
        }
    }

    #[test]
    fn greedy_continuation_is_deterministic_and_bounded() {
        let tid = ids();
        let mut rng = Rng::new(9);
        let e = gen_induction(&mut rng, &tid, 24);
        let mut be = crate::runtime::host::HostBackend::for_tests();
        let a = greedy_continuation(&mut be, &e.tokens, 8, false).unwrap();
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| (0..tid.vocab).contains(&t)));
        // Fresh backend, same weights: bit-identical stream.
        let mut be2 = crate::runtime::host::HostBackend::for_tests();
        assert_eq!(a, greedy_continuation(&mut be2, &e.tokens, 8, false).unwrap());
        // DMA attention flavor runs end-to-end too.
        assert_eq!(greedy_continuation(&mut be, &e.tokens, 4, true).unwrap().len(), 4);
        // max_new is clamped to the cache budget (last token is never cached).
        let cap = be.cache_len().saturating_sub(e.tokens.len()) + 1;
        let long = greedy_continuation(&mut be, &e.tokens, 10_000, false).unwrap();
        assert_eq!(long.len(), cap);
    }

    #[test]
    fn suite_runs_on_host_backend() {
        let mut be = crate::runtime::host::HostBackend::for_tests();
        let rows = run_suite(&mut be, &ids(), &[(2, 32)], 7).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.native));
            assert!((0.0..=1.0).contains(&r.dma));
        }
    }
}
