//! Serving configuration: loaded from `model_meta.json` (written by the
//! AOT exporter) plus engine settings from CLI/JSON overrides.

use crate::util::json::Json;
use anyhow::{anyhow, Context};
use std::path::{Path, PathBuf};

/// Model architecture constants (must match the AOT export).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub bm: usize,
    pub bn: usize,
    pub diag: usize,
    pub sink: usize,
}

/// Token-id conventions shared with `python/compile/tasks.py`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenIds {
    pub pad: i32,
    pub bos: i32,
    pub sep: i32,
    pub qry: i32,
    pub mrk: i32,
    pub eos: i32,
    pub payload_start: i32,
    pub vocab: i32,
}

/// Everything the runtime needs to know about the artifact bundle.
#[derive(Clone, Debug)]
pub struct MetaConfig {
    pub model: ModelConfig,
    pub tokens: TokenIds,
    pub param_order: Vec<String>,
    pub cache_len: usize,
    pub prefill_lens: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub attn_lens: Vec<usize>,
    pub attn_d: usize,
    pub eval_shapes: Vec<(usize, usize)>,
    /// Per-layer KV-cache precision policy exported by the AOT bundle
    /// (`kv_precision_policy.layers` in `model_meta.json`): the
    /// sink/diag windows the model was built around, used as the
    /// serving default when no `--kv-policy` override is given. Empty
    /// for pre-policy bundles.
    pub kv_precision_policies: Vec<crate::kvquant::KvPolicy>,
    pub artifact_dir: PathBuf,
}

impl MetaConfig {
    /// Load `model_meta.json` from an artifact directory.
    pub fn load(artifact_dir: impl AsRef<Path>) -> crate::Result<MetaConfig> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let path = dir.join("model_meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let num = |v: &Json, key: &str| -> crate::Result<usize> {
            v.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing numeric field {key}"))
        };
        let m = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let model = ModelConfig {
            vocab: num(m, "vocab")?,
            d_model: num(m, "d_model")?,
            n_layers: num(m, "n_layers")?,
            n_heads: num(m, "n_heads")?,
            n_kv_heads: num(m, "n_kv_heads")?,
            d_head: num(m, "d_head")?,
            max_seq: num(m, "max_seq")?,
            bm: num(m, "bm")?,
            bn: num(m, "bn")?,
            diag: num(m, "diag")?,
            sink: num(m, "sink")?,
        };
        let t = j.get("tokens").ok_or_else(|| anyhow!("missing tokens"))?;
        let tok = |key: &str| -> crate::Result<i32> {
            t.get(key)
                .and_then(Json::as_i64)
                .map(|v| v as i32)
                .ok_or_else(|| anyhow!("missing token id {key}"))
        };
        let tokens = TokenIds {
            pad: tok("PAD")?,
            bos: tok("BOS")?,
            sep: tok("SEP")?,
            qry: tok("QRY")?,
            mrk: tok("MRK")?,
            eos: tok("EOS")?,
            payload_start: tok("PAYLOAD_START")?,
            vocab: tok("VOCAB")?,
        };
        let usv = |key: &str| -> crate::Result<Vec<usize>> {
            Ok(j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {key}"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect())
        };
        let param_order = j
            .get("param_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing param_order"))?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let eval_shapes = j
            .get("eval_shapes")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|p| {
                        Some((p.idx(0)?.as_usize()?, p.idx(1)?.as_usize()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        // Per-layer KV precision policy (optional: pre-policy bundles
        // omit it). When present it must broadcast (one entry) or cover
        // every layer — a mismatched bundle is a build error, not
        // something to guess around at serving time.
        let kv_precision_policies = match j.get("kv_precision_policy") {
            None => Vec::new(),
            Some(p) => {
                let layers = p
                    .get("layers")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("kv_precision_policy.layers must be an array"))?;
                let parsed: Vec<crate::kvquant::KvPolicy> = layers
                    .iter()
                    .map(|l| -> crate::Result<crate::kvquant::KvPolicy> {
                        Ok(crate::kvquant::KvPolicy {
                            sink: num(l, "sink")?,
                            diag: num(l, "diag")?,
                        })
                    })
                    .collect::<crate::Result<_>>()?;
                if parsed.is_empty() || (parsed.len() != 1 && parsed.len() != model.n_layers) {
                    return Err(anyhow!(
                        "kv_precision_policy has {} entries; expected 1 or n_layers={}",
                        parsed.len(),
                        model.n_layers
                    ));
                }
                parsed
            }
        };
        Ok(MetaConfig {
            model,
            tokens,
            param_order,
            cache_len: num(&j, "cache_len")?,
            prefill_lens: usv("prefill_lens")?,
            decode_batches: usv("decode_batches")?,
            attn_lens: usv("attn_lens")?,
            attn_d: num(&j, "attn_d")?,
            eval_shapes,
            kv_precision_policies,
            artifact_dir: dir,
        })
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifact_dir.join(format!("{name}.hlo.txt"))
    }
}

/// Admission behavior under KV byte pressure (`--shed-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Pre-resilience behavior: over-budget groups queue until blocks
    /// free up (deferred admission), new submissions queue until
    /// `queue_limit`.
    Off,
    /// Graceful degradation: under sustained byte pressure the engine
    /// first shrinks the decoded-page cache budget and admits *new*
    /// sequences under the all-low KV precision policy (dual-format
    /// caches only — both planes exist, so flipping the read policy is
    /// always safe); if the projected demand still exceeds the pool,
    /// new submissions are shed with a structured
    /// `Rejected{retry_after_ms}` instead of queueing forever.
    Degrade,
    /// Spill-first shedding: under byte pressure the engine first spills
    /// reclaimable cold prefix pages to disk through the KV tier
    /// (requires `--kv-spill cold|aging`; the spill rung is a no-op
    /// without it) and re-checks the projection; only if demand still
    /// exceeds the pool are new submissions shed with
    /// `Rejected{retry_after_ms}`. No precision degradation — spilled
    /// pages reload bit-exactly.
    Spill,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> crate::Result<ShedPolicy> {
        match s {
            "off" => Ok(ShedPolicy::Off),
            "degrade" => Ok(ShedPolicy::Degrade),
            "spill" => Ok(ShedPolicy::Spill),
            other => Err(anyhow!("unknown shed policy '{other}' (off|degrade|spill)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShedPolicy::Off => "off",
            ShedPolicy::Degrade => "degrade",
            ShedPolicy::Spill => "spill",
        }
    }

    pub fn enabled(&self) -> bool {
        *self != ShedPolicy::Off
    }
}

/// Engine/serving knobs (CLI-overridable).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifact_dir: PathBuf,
    /// Attention mode for prefill: "native" or "dma".
    pub attention: String,
    /// Max tokens generated per request unless the request says less.
    pub max_new_tokens: usize,
    /// Maximum queued requests before admission starts rejecting.
    pub queue_limit: usize,
    /// Decode batch bucket sizes to use (must be exported).
    pub decode_batches: Vec<usize>,
    /// Scheduler time slice: max decode steps before re-checking prefill.
    pub decode_slice: usize,
    /// Chunked prefill: prompt tokens run per scheduler step per
    /// prefilling sequence. Rounded up to a whole number of KV pages by
    /// the engine; a long prompt no longer stalls decoding sequences for
    /// its full length.
    pub prefill_chunk: usize,
    /// Radix prefix cache: retain the full quantized pages of completed
    /// prefills keyed by their token content, so a request sharing a
    /// prompt prefix skips prefill for the shared pages (quantized
    /// formats only; ignored for the f32 cache).
    pub prefix_cache: bool,
    /// KV-cache storage format: `f32` (legacy batch slots), `mxfp8-high`,
    /// `nvfp4-low`, or `dual` (both copies; the page policy picks).
    /// Quantized formats require a backend with a paged decode path
    /// (the host backend; PJRT executables are f32-only).
    pub kv_format: crate::kvquant::KvFormat,
    /// Page precision policies for quantized caches: sink/frontier
    /// windows in tokens (pages there decode MXFP8-high, the body
    /// NVFP4-low). One entry broadcasts to every layer; otherwise one
    /// entry per layer (`--kv-policy l0:S/D;l1:S/D;...`).
    pub kv_precision_policies: Vec<crate::kvquant::KvPolicy>,
    /// Intra-step worker threads (`--threads`): the backend fans the
    /// batched decode across sequences and the model fans each layer's
    /// kv-head attention loop, all into disjoint output buffers — token
    /// streams are identical at any thread count. 1 = fully serial.
    pub threads: usize,
    /// Per-slot byte budget for decoded-page f32 tiles
    /// (`--decoded-cache-mb`): immutable quantized pages dequantize once
    /// and are reused every decode step until evicted LRU. 0 disables
    /// the cache (over-budget tiles decode into a reused scratch slot).
    /// The *live* decoded bytes are charged against the pool's byte
    /// budget at admission (on top of quantized bytes) and included in
    /// `kv_bytes_peak`, so a memory-tight deployment cannot over-admit
    /// while hot decoded tiles hold real memory.
    pub decoded_cache_bytes: usize,
    /// Physical KV byte budget the admission pool is sized from
    /// (`--kv-budget-mb`). 0 (the default) derives it from the decode
    /// slots: `max_slots x cache_len x f32 bytes/token` — what the f32
    /// batch slots would occupy. Memory-tight deployments pin it
    /// explicitly; quantized formats get proportionally more admission
    /// blocks either way.
    pub kv_budget_bytes: usize,
    /// Speculative decoding mode (`--spec off|prompt-lookup`): when
    /// enabled, each decoding candidate drafts tokens from its own
    /// prompt+output history, verifies the whole chain in one batched
    /// multi-token decode pass, and rolls rejected positions back out of
    /// the KV cache. Output distributions are exactly preserved (greedy
    /// bit-replays the non-speculative stream) — see [`crate::spec`].
    pub spec: crate::spec::SpecMode,
    /// Max draft tokens verified per decode step (`--spec-k`). Higher
    /// values amortize more per-step overhead on repetitive text but
    /// waste verify work when drafts miss; 4 is a good default for
    /// prompt-lookup drafting.
    pub spec_k: usize,
    /// Layer-probe sampling cadence (`--metrics-sample-n`): every Nth
    /// decode step additionally times each layer's attention and KV
    /// quantize-on-append into the telemetry histograms. 0 (the
    /// default) disables the probe — the decode hot path then contains
    /// no clock reads. Only takes effect when the engine runs with
    /// telemetry attached.
    pub metrics_sample_n: usize,
    /// Server-wide wall-clock budget per request in milliseconds,
    /// measured from submission and enforced at the engine step
    /// boundary (`--request-timeout-ms`); 0 disables. Requests that
    /// exceed it finish with reason `timeout` and release their pool
    /// bytes like a cancel.
    pub request_timeout_ms: u64,
    /// Max milliseconds a request may wait *queued* before admission
    /// (`--queue-timeout-ms`); 0 disables. Bounds time-to-first-work
    /// under overload so clients can retry elsewhere.
    pub queue_timeout_ms: u64,
    /// Admission behavior under KV byte pressure (`--shed-policy`).
    pub shed_policy: ShedPolicy,
    /// Tiered KV memory mode (`--kv-spill off|cold|aging`): `cold`
    /// spills LRU prefix pages to disk under pressure and reloads them
    /// bit-exactly on a radix hit; `aging` additionally walks idle
    /// pages down the `hot → aged → spilled` schedule, dropping the
    /// high-precision planes of pages outside each layer's sink window
    /// first. Requires `--prefix-cache` (the spill unit is a radix
    /// page). See [`crate::kvquant::tier`].
    pub kv_spill: crate::kvquant::tier::TierMode,
    /// Directory for the per-worker spill files (`--kv-spill-dir`).
    /// `None` uses a process-scoped directory under the OS temp dir;
    /// files are deleted when the engine drops either way.
    pub kv_spill_dir: Option<PathBuf>,
    /// Idle milliseconds before a resident page ages (`--kv-age-ms`);
    /// aged pages spill after twice this. Only meaningful with
    /// `--kv-spill aging`.
    pub kv_age_ms: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifact_dir: PathBuf::from("artifacts"),
            attention: "dma".into(),
            max_new_tokens: 32,
            queue_limit: 256,
            decode_batches: vec![1, 2, 4],
            decode_slice: 8,
            prefill_chunk: 32,
            prefix_cache: false,
            kv_format: crate::kvquant::KvFormat::F32,
            kv_precision_policies: vec![crate::kvquant::KvPolicy::default()],
            threads: 1,
            decoded_cache_bytes: crate::kvquant::DECODED_CACHE_BYTES,
            kv_budget_bytes: 0,
            spec: crate::spec::SpecMode::Off,
            spec_k: 4,
            metrics_sample_n: 0,
            request_timeout_ms: 0,
            queue_timeout_ms: 0,
            shed_policy: ShedPolicy::Off,
            kv_spill: crate::kvquant::tier::TierMode::Off,
            kv_spill_dir: None,
            kv_age_ms: 250,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_json() -> String {
        r#"{
          "model": {"vocab": 64, "d_model": 128, "n_layers": 2,
                    "n_heads": 4, "n_kv_heads": 4, "d_head": 32,
                    "d_ff": 256, "max_seq": 512, "rope_theta": 10000.0,
                    "bm": 32, "bn": 32, "diag": 64, "sink": 32},
          "tokens": {"PAD":0,"BOS":1,"SEP":2,"QRY":3,"MRK":4,"EOS":5,
                     "PAYLOAD_START":6,"VOCAB":64},
          "param_order": ["embed","layers.0.ln1","ln_f"],
          "cache_len": 320,
          "prefill_lens": [64,128,256],
          "decode_batches": [1,2,4],
          "attn_lens": [128,512],
          "attn_d": 64,
          "eval_shapes": [[8,96],[8,224]],
          "kv_precision_policy": {"layers": [{"sink": 32, "diag": 64},
                                             {"sink": 16, "diag": 32}]},
          "artifacts": {}
        }"#
        .to_string()
    }

    #[test]
    fn parse_meta() {
        let dir = std::env::temp_dir().join(format!("dma_meta_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("model_meta.json"), meta_json()).unwrap();
        let m = MetaConfig::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 64);
        assert_eq!(m.model.n_layers, 2);
        assert_eq!(m.tokens.qry, 3);
        assert_eq!(m.cache_len, 320);
        assert_eq!(m.prefill_lens, vec![64, 128, 256]);
        assert_eq!(m.eval_shapes, vec![(8, 96), (8, 224)]);
        assert_eq!(m.param_order.len(), 3);
        assert_eq!(
            m.kv_precision_policies,
            vec![
                crate::kvquant::KvPolicy { sink: 32, diag: 64 },
                crate::kvquant::KvPolicy { sink: 16, diag: 32 },
            ]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_without_policy_defaults_empty() {
        let dir = std::env::temp_dir()
            .join(format!("dma_meta_nopolicy_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let stripped = meta_json().replace(
            r#""kv_precision_policy": {"layers": [{"sink": 32, "diag": 64},
                                             {"sink": 16, "diag": 32}]},"#,
            "",
        );
        assert!(!stripped.contains("kv_precision_policy"));
        std::fs::write(dir.join("model_meta.json"), stripped).unwrap();
        let m = MetaConfig::load(&dir).unwrap();
        assert!(m.kv_precision_policies.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_policy_layer_count_is_checked() {
        // 3 entries for a 2-layer model: the loader must refuse the
        // bundle rather than mis-assign policies.
        let dir = std::env::temp_dir()
            .join(format!("dma_meta_badpolicy_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = meta_json().replace(
            r#"{"layers": [{"sink": 32, "diag": 64},
                                             {"sink": 16, "diag": 32}]}"#,
            r#"{"layers": [{"sink": 32, "diag": 64}, {"sink": 16, "diag": 32},
                           {"sink": 8, "diag": 8}]}"#,
        );
        std::fs::write(dir.join("model_meta.json"), bad).unwrap();
        let err = MetaConfig::load(&dir).unwrap_err();
        assert!(err.to_string().contains("expected 1 or n_layers"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_meta_is_helpful() {
        let err = MetaConfig::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn engine_config_defaults_to_f32_cache() {
        let cfg = EngineConfig::default();
        assert_eq!(cfg.kv_format, crate::kvquant::KvFormat::F32);
        assert_eq!(cfg.kv_precision_policies.len(), 1);
        assert_eq!(cfg.kv_precision_policies[0].sink, 128);
        assert_eq!(cfg.kv_precision_policies[0].diag, 128);
        assert!(!cfg.prefix_cache);
        assert!(cfg.prefill_chunk > 0);
        assert_eq!(cfg.threads, 1);
        assert_eq!(cfg.decoded_cache_bytes, crate::kvquant::DECODED_CACHE_BYTES);
        assert_eq!(cfg.kv_budget_bytes, 0, "0 = derive from decode slots");
        assert_eq!(cfg.spec, crate::spec::SpecMode::Off, "speculation off by default");
        assert_eq!(cfg.spec_k, 4);
        assert_eq!(cfg.metrics_sample_n, 0, "layer probe off by default");
        assert_eq!(cfg.request_timeout_ms, 0, "no deadline by default");
        assert_eq!(cfg.queue_timeout_ms, 0);
        assert_eq!(cfg.shed_policy, ShedPolicy::Off);
        assert_eq!(cfg.kv_spill, crate::kvquant::tier::TierMode::Off);
        assert!(cfg.kv_spill_dir.is_none(), "spill dir derived from temp dir");
        assert_eq!(cfg.kv_age_ms, 250);
    }

    #[test]
    fn shed_policy_parses_and_names() {
        assert_eq!(ShedPolicy::parse("off").unwrap(), ShedPolicy::Off);
        assert_eq!(ShedPolicy::parse("degrade").unwrap(), ShedPolicy::Degrade);
        assert_eq!(ShedPolicy::parse("spill").unwrap(), ShedPolicy::Spill);
        assert!(ShedPolicy::parse("bogus").is_err());
        assert_eq!(ShedPolicy::Degrade.name(), "degrade");
        assert_eq!(ShedPolicy::Spill.name(), "spill");
        assert!(!ShedPolicy::Off.enabled());
        assert!(ShedPolicy::Degrade.enabled());
        assert!(ShedPolicy::Spill.enabled());
    }
}
