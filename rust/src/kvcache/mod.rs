//! KV-cache management.
//!
//! Two cooperating pieces, mirroring how vLLM-style paged attention
//! adapts to *bucketed* PJRT executables (static shapes):
//!
//! * [`BlockPool`] — vLLM-style paged accounting: fixed-size token
//!   blocks, per-sequence block tables, refcounted sharing (prefix
//!   reuse), capacity-based admission. Admission is *format-aware*: the
//!   pool is sized from a byte budget and a bytes-per-token cost
//!   ([`BlockPool::with_byte_budget`]), so an MXFP-quantized cache
//!   ([`crate::kvquant`]) admits proportionally more tokens than f32
//!   within the same physical budget.
//! * [`SlotCache`] — the physical layout: the decode executable takes
//!   `[n_layers, B, H_kv, C, d_head]` cache tensors, so each running
//!   sequence owns one batch slot; this type packs/unpacks per-slot
//!   caches into the flat batch literals.
//! * [`SeqKv`] — a running sequence's cache payload: either a
//!   full-precision [`SlotKv`] batch slot or a quantized paged
//!   [`crate::kvquant::QuantSlotKv`], selected by
//!   `EngineConfig::kv_format`.

use anyhow::{anyhow, bail};
use std::collections::BTreeMap;

// ---------------------------------------------------------------------
// Paged block pool (admission accounting)
// ---------------------------------------------------------------------

pub type SeqId = u64;

#[derive(Clone, Debug)]
struct SeqEntry {
    blocks: Vec<usize>,
    tokens: usize,
}

/// Paged KV block pool with refcounted blocks.
pub struct BlockPool {
    block_tokens: usize,
    /// Accounting cost of one cached token in bytes (all layers/heads,
    /// K + V, at the cache's storage format). 1 when the pool was built
    /// token-denominated via [`BlockPool::new`].
    bytes_per_token: usize,
    refcount: Vec<u32>,
    free: Vec<usize>,
    seqs: BTreeMap<SeqId, SeqEntry>,
    /// Per-sequence byte credits from precision aging
    /// ([`crate::kvquant::tier`]): a radix page whose high planes were
    /// dropped physically shrank in place, but its block still occupies
    /// one accounting slot — the credit lets [`Self::bytes_in_use`]
    /// report the real residency so admission can use the freed bytes.
    credits: BTreeMap<SeqId, usize>,
    /// Running sum of `credits` (kept incrementally; `bytes_in_use` is
    /// on the admission hot path).
    credited: usize,
}

impl BlockPool {
    pub fn new(num_blocks: usize, block_tokens: usize) -> BlockPool {
        BlockPool {
            block_tokens,
            bytes_per_token: 1,
            refcount: vec![0; num_blocks],
            free: (0..num_blocks).rev().collect(),
            seqs: BTreeMap::new(),
            credits: BTreeMap::new(),
            credited: 0,
        }
    }

    /// Size the pool from a physical byte budget and a per-token storage
    /// cost: cheaper formats get proportionally more blocks. This is how
    /// the engine turns `kv_format` into admission capacity — e.g. an
    /// `nvfp4-low` cache (~6x fewer bytes/token) yields ~6x the blocks of
    /// f32 within the same budget.
    pub fn with_byte_budget(
        total_bytes: usize,
        block_tokens: usize,
        bytes_per_token: usize,
    ) -> BlockPool {
        assert!(block_tokens > 0 && bytes_per_token > 0);
        let num_blocks = total_bytes / (block_tokens * bytes_per_token);
        let mut pool = BlockPool::new(num_blocks, block_tokens);
        pool.bytes_per_token = bytes_per_token;
        pool
    }

    pub fn num_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn bytes_per_token(&self) -> usize {
        self.bytes_per_token
    }

    /// Accounting capacity in bytes.
    pub fn bytes_capacity(&self) -> usize {
        self.refcount.len() * self.block_tokens * self.bytes_per_token
    }

    /// Bytes of allocated (referenced) blocks, net of aging credits.
    pub fn bytes_in_use(&self) -> usize {
        let used = self.refcount.iter().filter(|&&r| r > 0).count();
        (used * self.block_tokens * self.bytes_per_token).saturating_sub(self.credited)
    }

    /// Credit `bytes` back against `seq`'s blocks after its pages were
    /// precision-aged (their high planes dropped in place). The credit
    /// is capped at the sequence's accounting bytes — a block can never
    /// report negative residency — and cleared when the sequence is
    /// released (the whole block returns to the pool then).
    pub fn credit_bytes(&mut self, seq: SeqId, bytes: usize) -> crate::Result<()> {
        let entry = self
            .seqs
            .get(&seq)
            .ok_or_else(|| anyhow!("credit for unknown sequence {seq}"))?;
        let cap = entry.blocks.len() * self.block_tokens * self.bytes_per_token;
        let cur = self.credits.entry(seq).or_insert(0);
        let add = bytes.min(cap.saturating_sub(*cur));
        *cur += add;
        self.credited += add;
        Ok(())
    }

    /// Total outstanding aging credits.
    pub fn credited_bytes(&self) -> usize {
        self.credited
    }

    pub fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.free.len()
    }

    /// Can `blocks` blocks be allocated right now? (Group admission sums
    /// several allocations — shared prompt plus per-candidate budgets —
    /// whose block counts round independently.)
    pub fn can_admit_blocks(&self, blocks: usize) -> bool {
        blocks <= self.free.len()
    }

    /// Accounting bytes of one block.
    pub fn block_bytes(&self) -> usize {
        self.block_tokens * self.bytes_per_token
    }

    /// Allocate blocks for a new sequence.
    pub fn allocate(&mut self, seq: SeqId, tokens: usize) -> crate::Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already allocated");
        }
        let need = self.blocks_needed(tokens);
        if need > self.free.len() {
            bail!("out of KV blocks: need {need}, free {}", self.free.len());
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.refcount[b] = 1;
            blocks.push(b);
        }
        self.seqs.insert(seq, SeqEntry { blocks, tokens });
        Ok(())
    }

    /// Extend a sequence by `n` tokens (decode), allocating on block
    /// boundaries.
    pub fn extend(&mut self, seq: SeqId, n: usize) -> crate::Result<()> {
        let bt = self.block_tokens;
        let entry = self
            .seqs
            .get_mut(&seq)
            .ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        let need_total = (entry.tokens + n).div_ceil(bt);
        let extra = need_total.saturating_sub(entry.blocks.len());
        if extra > self.free.len() {
            bail!("out of KV blocks extending seq {seq}");
        }
        for _ in 0..extra {
            let b = self.free.pop().unwrap();
            self.refcount[b] = 1;
            entry.blocks.push(b);
        }
        entry.tokens += n;
        Ok(())
    }

    /// Shrink a sequence to `tokens` tokens (speculative-decode KV
    /// rollback), re-crediting whole blocks past the new boundary.
    /// Popped blocks decrement their refcount and return to the free
    /// list at zero — a block shared with a fork or a radix-cache entry
    /// survives in the other holder, mirroring how the quantized store
    /// drops only *its* `Arc` on shared pages.
    pub fn truncate(&mut self, seq: SeqId, tokens: usize) -> crate::Result<()> {
        let bt = self.block_tokens;
        let entry = self
            .seqs
            .get_mut(&seq)
            .ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        if tokens > entry.tokens {
            bail!("truncate would grow seq {seq}: {tokens} > {}", entry.tokens);
        }
        let keep = tokens.div_ceil(bt);
        while entry.blocks.len() > keep {
            let b = entry.blocks.pop().unwrap();
            self.refcount[b] -= 1;
            if self.refcount[b] == 0 {
                self.free.push(b);
            }
        }
        entry.tokens = tokens;
        let blocks = entry.blocks.len();
        // Popped blocks re-credit in full, so any aging credit against
        // them must shrink to keep the per-seq cap.
        if let Some(c) = self.credits.get_mut(&seq) {
            let cap = blocks * bt * self.bytes_per_token;
            if *c > cap {
                self.credited -= *c - cap;
                *c = cap;
            }
        }
        Ok(())
    }

    /// Fork a sequence sharing all current blocks (copy-on-write prefix
    /// reuse, e.g. beam candidates).
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> crate::Result<()> {
        if self.seqs.contains_key(&child) {
            bail!("child {child} exists");
        }
        let entry = self
            .seqs
            .get(&parent)
            .ok_or_else(|| anyhow!("unknown parent {parent}"))?
            .clone();
        for &b in &entry.blocks {
            self.refcount[b] += 1;
        }
        self.seqs.insert(child, entry);
        Ok(())
    }

    /// Register `child` as a new one-block sequence sharing the `idx`-th
    /// block of `parent` (refcount++). This is how the radix prefix cache
    /// ([`crate::coordinator::radix`]) retains admission accounting for
    /// one cached page after the sequence that produced it releases: the
    /// cache forks the block out of the running sequence's table, and
    /// later sharers fork the cache node's entry in turn.
    pub fn fork_block(&mut self, parent: SeqId, child: SeqId, idx: usize) -> crate::Result<()> {
        if self.seqs.contains_key(&child) {
            bail!("child {child} exists");
        }
        let entry = self
            .seqs
            .get(&parent)
            .ok_or_else(|| anyhow!("unknown parent {parent}"))?;
        let Some(&b) = entry.blocks.get(idx) else {
            bail!("parent {parent} has no block {idx}");
        };
        self.refcount[b] += 1;
        self.seqs
            .insert(child, SeqEntry { blocks: vec![b], tokens: self.block_tokens });
        Ok(())
    }

    /// Release a sequence; blocks return to the pool when refcount hits 0.
    pub fn release(&mut self, seq: SeqId) -> crate::Result<()> {
        let entry = self
            .seqs
            .remove(&seq)
            .ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        if let Some(c) = self.credits.remove(&seq) {
            self.credited -= c;
        }
        for b in entry.blocks {
            self.refcount[b] -= 1;
            if self.refcount[b] == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    pub fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(&seq).map(|e| e.tokens)
    }

    /// Largest refcount across a sequence's blocks — 1 means no other
    /// sequence shares any of them (the eviction-safety signal: releasing
    /// such a sequence really frees its blocks).
    pub fn seq_max_refcount(&self, seq: SeqId) -> Option<u32> {
        self.seqs
            .get(&seq)
            .map(|e| e.blocks.iter().map(|&b| self.refcount[b]).max().unwrap_or(0))
    }

    /// Invariant check used by property tests.
    pub fn check_invariants(&self) -> crate::Result<()> {
        let used: usize = self.refcount.iter().filter(|&&r| r > 0).count();
        if used + self.free.len() != self.refcount.len() {
            bail!("block accounting leak: used {used} + free {} != {}",
                  self.free.len(), self.refcount.len());
        }
        for (id, e) in &self.seqs {
            if e.blocks.len() != e.tokens.div_ceil(self.block_tokens) {
                bail!("seq {id}: {} blocks for {} tokens", e.blocks.len(), e.tokens);
            }
            for &b in &e.blocks {
                if self.refcount[b] == 0 {
                    bail!("seq {id} references freed block {b}");
                }
            }
        }
        let mut total = 0usize;
        for (id, &c) in &self.credits {
            let Some(e) = self.seqs.get(id) else {
                bail!("credit for released sequence {id}");
            };
            let cap = e.blocks.len() * self.block_tokens * self.bytes_per_token;
            if c > cap {
                bail!("seq {id} credit {c} exceeds its {cap} accounting bytes");
            }
            total += c;
        }
        if total != self.credited {
            bail!("credit ledger drift: entries sum {total}, running total {}", self.credited);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Slotted batch cache (physical layout for bucketed executables)
// ---------------------------------------------------------------------

/// Per-slot KV storage: flat `[n_layers, H_kv, C, d_head]` f32.
#[derive(Clone)]
pub struct SlotKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: usize,
}

/// Packs per-sequence caches into `[n_layers, B, H_kv, C, d_head]` batch
/// literals for the decode executable and scatters the outputs back.
pub struct SlotCache {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub cache_len: usize,
    pub d_head: usize,
}

impl SlotCache {
    pub fn new(n_layers: usize, n_kv_heads: usize, cache_len: usize, d_head: usize) -> Self {
        SlotCache { n_layers, n_kv_heads, cache_len, d_head }
    }

    pub fn slot_elems(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.cache_len * self.d_head
    }

    pub fn empty_slot(&self) -> SlotKv {
        SlotKv { k: vec![0.0; self.slot_elems()], v: vec![0.0; self.slot_elems()], pos: 0 }
    }

    /// Build a slot from a prefill output cache shaped
    /// `[n_layers, H_kv, L, d_head]` (L <= cache_len), zero-padded.
    pub fn slot_from_prefill(&self, kc: &[f32], vc: &[f32], l: usize) -> crate::Result<SlotKv> {
        let src_elems = self.n_layers * self.n_kv_heads * l * self.d_head;
        anyhow::ensure!(kc.len() == src_elems && vc.len() == src_elems,
                        "prefill cache size {} != expected {src_elems}", kc.len());
        anyhow::ensure!(l <= self.cache_len, "prefill len {l} > cache {}", self.cache_len);
        let mut slot = self.empty_slot();
        let (c, dh) = (self.cache_len, self.d_head);
        for li in 0..self.n_layers {
            for h in 0..self.n_kv_heads {
                let src = (li * self.n_kv_heads + h) * l * dh;
                let dst = (li * self.n_kv_heads + h) * c * dh;
                slot.k[dst..dst + l * dh].copy_from_slice(&kc[src..src + l * dh]);
                slot.v[dst..dst + l * dh].copy_from_slice(&vc[src..src + l * dh]);
            }
        }
        slot.pos = l;
        Ok(slot)
    }

    /// Gather `slots` into one `[n_layers, B, H_kv, C, d_head]` batch
    /// buffer (missing slots are zero).
    pub fn gather_batch(&self, slots: &[Option<&SlotKv>], out_k: &mut [f32], out_v: &mut [f32]) {
        let b = slots.len();
        let (c, dh) = (self.cache_len, self.d_head);
        let stride_h = c * dh;
        let stride_b = self.n_kv_heads * stride_h;
        let stride_l = b * stride_b;
        out_k.fill(0.0);
        out_v.fill(0.0);
        for (bi, slot) in slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            for li in 0..self.n_layers {
                for h in 0..self.n_kv_heads {
                    let src = (li * self.n_kv_heads + h) * stride_h;
                    let dst = li * stride_l + bi * stride_b + h * stride_h;
                    out_k[dst..dst + stride_h].copy_from_slice(&s.k[src..src + stride_h]);
                    out_v[dst..dst + stride_h].copy_from_slice(&s.v[src..src + stride_h]);
                }
            }
        }
    }

    /// Scatter the decode executable's updated batch caches back into the
    /// slots (only rows that exist).
    pub fn scatter_batch(&self, in_k: &[f32], in_v: &[f32], slots: &mut [Option<&mut SlotKv>]) {
        let b = slots.len();
        let (c, dh) = (self.cache_len, self.d_head);
        let stride_h = c * dh;
        let stride_b = self.n_kv_heads * stride_h;
        let stride_l = b * stride_b;
        for (bi, slot) in slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            for li in 0..self.n_layers {
                for h in 0..self.n_kv_heads {
                    let dst = (li * self.n_kv_heads + h) * stride_h;
                    let src = li * stride_l + bi * stride_b + h * stride_h;
                    s.k[dst..dst + stride_h].copy_from_slice(&in_k[src..src + stride_h]);
                    s.v[dst..dst + stride_h].copy_from_slice(&in_v[src..src + stride_h]);
                }
            }
        }
    }

    pub fn batch_elems(&self, b: usize) -> usize {
        self.n_layers * b * self.n_kv_heads * self.cache_len * self.d_head
    }
}

// ---------------------------------------------------------------------
// Per-sequence cache payload (format dispatch)
// ---------------------------------------------------------------------

/// The cache a running sequence owns: full-precision batch slot or
/// quantized paged store. Backends dispatch on the variant in `decode`;
/// the engine picks the variant from `EngineConfig::kv_format` right
/// after prefill.
pub enum SeqKv {
    F32(SlotKv),
    Quant(crate::kvquant::QuantSlotKv),
}

impl SeqKv {
    /// Tokens currently cached.
    pub fn pos(&self) -> usize {
        match self {
            SeqKv::F32(s) => s.pos,
            SeqKv::Quant(s) => s.pos,
        }
    }

    /// Resident bytes of the cache payload. F32 slots are pre-allocated
    /// to the full engine cache length (that is their real footprint);
    /// quantized stores grow page-by-page with the sequence.
    pub fn resident_bytes(&self) -> usize {
        match self {
            SeqKv::F32(s) => (s.k.len() + s.v.len()) * 4,
            // Quantized payload plus the slot's decoded-page tiles —
            // the cache is real memory the sequence holds, bounded by
            // its byte budget but outside the BlockPool's quantized-byte
            // admission accounting.
            SeqKv::Quant(s) => s.quantized_bytes() + s.decoded_bytes(),
        }
    }

    /// Fork this cache for a sibling candidate of a sequence group. The
    /// quantized store forks in O(pages) — full pages `Arc`-shared, the
    /// partial frontier page copy-on-write, decoded-page caches shared
    /// so siblings hit each other's dequantized prefix tiles. The f32
    /// slot has no page structure, so its fork is a deep copy (which is
    /// also what the admission accounting charges it for).
    pub fn fork(&self) -> SeqKv {
        match self {
            SeqKv::F32(s) => SeqKv::F32(s.clone()),
            SeqKv::Quant(q) => SeqKv::Quant(q.fork()),
        }
    }

    /// Roll the cache back to `pos` tokens (speculative-decode rejection).
    /// The f32 slot is position-addressed over a pre-allocated buffer, so
    /// rollback is just the position: the decode path writes row `pos`
    /// and attends rows `0..pos+1`, so stale bytes past the frontier are
    /// unreachable and the replayed tokens overwrite them bit-exactly.
    /// The quantized store pops rows/pages copy-on-write — see
    /// [`crate::kvquant::QuantSlotKv::truncate_to`].
    pub fn truncate(&mut self, pos: usize) {
        match self {
            SeqKv::F32(s) => {
                assert!(pos <= s.pos, "truncate {pos} > pos {}", s.pos);
                s.pos = pos;
            }
            SeqKv::Quant(q) => q.truncate_to(pos),
        }
    }

    /// Resident bytes of the decoded-page caches alone (0 for f32).
    /// Sibling candidates share caches, so a group must count this once,
    /// not per candidate — see the engine's admission sampling.
    pub fn decoded_bytes(&self) -> usize {
        match self {
            SeqKv::F32(_) => 0,
            SeqKv::Quant(s) => s.decoded_bytes(),
        }
    }

    pub fn as_f32(&self) -> Option<&SlotKv> {
        match self {
            SeqKv::F32(s) => Some(s),
            SeqKv::Quant(_) => None,
        }
    }

    pub fn as_f32_mut(&mut self) -> Option<&mut SlotKv> {
        match self {
            SeqKv::F32(s) => Some(s),
            SeqKv::Quant(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release() {
        let mut p = BlockPool::new(10, 16);
        p.allocate(1, 40).unwrap(); // 3 blocks
        assert_eq!(p.free_blocks(), 7);
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 10);
        p.check_invariants().unwrap();
    }

    #[test]
    fn extend_on_boundary() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 16).unwrap(); // exactly 1 block
        assert_eq!(p.free_blocks(), 3);
        p.extend(1, 1).unwrap(); // crosses into block 2
        assert_eq!(p.free_blocks(), 2);
        for _ in 0..15 {
            p.extend(1, 1).unwrap(); // fills block 2, no new alloc
        }
        assert_eq!(p.free_blocks(), 2);
        p.extend(1, 1).unwrap();
        assert_eq!(p.free_blocks(), 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut p = BlockPool::new(4, 16);
        assert!(p.can_admit(64));
        assert!(!p.can_admit(65));
        p.allocate(1, 48).unwrap();
        assert!(p.can_admit(16));
        assert!(!p.can_admit(17));
    }

    #[test]
    fn oom_is_error_not_panic() {
        let mut p = BlockPool::new(2, 16);
        p.allocate(1, 32).unwrap();
        assert!(p.allocate(2, 1).is_err());
        assert!(p.extend(1, 1).is_err());
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_blocks() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap();
        p.fork(1, 2).unwrap();
        assert_eq!(p.free_blocks(), 2); // shared, no new blocks
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 2); // child still holds them
        p.release(2).unwrap();
        assert_eq!(p.free_blocks(), 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn double_allocate_rejected() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 16).unwrap();
        assert!(p.allocate(1, 16).is_err());
    }

    #[test]
    fn fork_block_shares_one_block() {
        let mut p = BlockPool::with_byte_budget(8 * 16 * 100, 16, 100);
        p.allocate(1, 40).unwrap(); // 3 blocks
        let used = p.bytes_in_use();
        p.fork_block(1, 100, 1).unwrap();
        // Shared block: no new bytes, no new blocks.
        assert_eq!(p.bytes_in_use(), used);
        assert_eq!(p.free_blocks(), 5);
        assert_eq!(p.seq_tokens(100), Some(16));
        assert_eq!(p.seq_max_refcount(100), Some(2));
        assert_eq!(p.seq_max_refcount(1), Some(2)); // block 1 is shared
        assert_eq!(p.seq_max_refcount(7), None);
        // Parent releases; the forked child keeps its block alive.
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 7);
        assert_eq!(p.bytes_in_use(), 16 * 100);
        p.release(100).unwrap();
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();

        // Errors: unknown parent, out-of-range block, duplicate child.
        assert!(p.fork_block(1, 101, 0).is_err());
        p.allocate(2, 16).unwrap();
        assert!(p.fork_block(2, 102, 5).is_err());
        p.fork_block(2, 102, 0).unwrap();
        assert!(p.fork_block(2, 102, 0).is_err());
        p.check_invariants().unwrap();
    }

    #[test]
    fn truncate_recredits_whole_blocks() {
        let mut p = BlockPool::with_byte_budget(8 * 16 * 100, 16, 100);
        p.allocate(1, 44).unwrap(); // 3 blocks
        assert_eq!(p.bytes_in_use(), 3 * 16 * 100);
        // Within the last block: no blocks freed, token count drops.
        p.truncate(1, 36).unwrap();
        assert_eq!(p.free_blocks(), 5);
        assert_eq!(p.seq_tokens(1), Some(36));
        // Across a boundary: the trailing block is re-credited.
        p.truncate(1, 30).unwrap();
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(p.bytes_in_use(), 2 * 16 * 100);
        p.check_invariants().unwrap();
        // A block shared with a fork survives the parent's rollback.
        p.fork(1, 2).unwrap();
        p.truncate(1, 0).unwrap();
        assert_eq!(p.free_blocks(), 6); // child still holds both blocks
        assert_eq!(p.seq_tokens(2), Some(30));
        p.check_invariants().unwrap();
        // Growing via truncate is an error; unknown seq is an error.
        assert!(p.truncate(1, 1).is_err());
        assert!(p.truncate(9, 0).is_err());
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn property_random_ops_keep_invariants() {
        // Interleaves allocate / extend / fork / fork_block / truncate /
        // release and asserts, beyond the structural invariants, that the
        // byte accounting matches a from-scratch recount every step —
        // fork carries real traffic now (radix prefix cache), so shared
        // blocks must be counted exactly once however many sequences hold
        // them, and truncate (speculative rollback) must re-credit
        // exactly the popped whole blocks.
        crate::util::prop::check("blockpool invariants", 25, |rng| {
            let mut p = BlockPool::with_byte_budget(32 * 8 * 64, 8, 64);
            let mut live: Vec<SeqId> = Vec::new();
            let mut next_id: SeqId = 0;
            for _ in 0..300 {
                match rng.below(6) {
                    0 => {
                        let toks = rng.int_in(1, 40) as usize;
                        if p.can_admit(toks) {
                            p.allocate(next_id, toks).map_err(|e| e.to_string())?;
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let _ = p.extend(live[i], rng.int_in(1, 8) as usize);
                        }
                    }
                    2 => {
                        if !live.is_empty() && p.free_blocks() > 4 {
                            let i = rng.below(live.len() as u64) as usize;
                            if p.fork(live[i], next_id).is_ok() {
                                live.push(next_id);
                                next_id += 1;
                            }
                        }
                    }
                    3 => {
                        // Radix-cache-style single-block fork.
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let parent = live[i];
                            let nb = p.seqs[&parent].blocks.len();
                            let idx = rng.below(nb as u64) as usize;
                            if p.fork_block(parent, next_id, idx).is_ok() {
                                live.push(next_id);
                                next_id += 1;
                            }
                        }
                    }
                    4 => {
                        // Speculative-rollback-style truncation.
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let id = live[i];
                            let toks = p.seq_tokens(id).unwrap();
                            let cut = rng.below(toks as u64 + 1) as usize;
                            p.truncate(id, cut).map_err(|e| e.to_string())?;
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let id = live.swap_remove(i);
                            p.release(id).map_err(|e| e.to_string())?;
                        }
                    }
                }
                p.check_invariants().map_err(|e| e.to_string())?;
                // Byte accounting: recount from the refcount plane.
                let used = p.refcount.iter().filter(|&&r| r > 0).count();
                crate::prop_assert!(
                    p.bytes_in_use() == used * 8 * 64,
                    "bytes_in_use {} != recount {}",
                    p.bytes_in_use(),
                    used * 8 * 64
                );
                crate::prop_assert!(
                    p.bytes_in_use() <= p.bytes_capacity(),
                    "in use past capacity"
                );
                // Every referenced block is reachable from some live seq
                // and refcounts equal the number of holders.
                let mut holders = vec![0u32; p.num_blocks()];
                for e in p.seqs.values() {
                    for &b in &e.blocks {
                        holders[b] += 1;
                    }
                }
                crate::prop_assert!(
                    holders == p.refcount,
                    "refcount plane diverged from holder recount"
                );
            }
            // Drain everything: the pool must come back whole.
            for id in live {
                p.release(id).map_err(|e| e.to_string())?;
            }
            crate::prop_assert!(p.free_blocks() == p.num_blocks(), "leak after drain");
            crate::prop_assert!(p.bytes_in_use() == 0, "bytes leak after drain");
            Ok(())
        });
    }

    #[test]
    fn byte_budget_scales_blocks_with_format_cost() {
        // Same physical budget, cheaper format => proportionally more
        // blocks (the format-aware admission the engine relies on).
        let budget = 16 * 1024usize;
        let f32_pool = BlockPool::with_byte_budget(budget, 16, 1024);
        assert_eq!(f32_pool.num_blocks(), 1);
        assert_eq!(f32_pool.bytes_capacity(), budget);
        let nvfp4_pool = BlockPool::with_byte_budget(budget, 16, 176);
        assert_eq!(nvfp4_pool.num_blocks(), 5);
        assert!(nvfp4_pool.num_blocks() >= 3 * f32_pool.num_blocks());
    }

    #[test]
    fn bytes_in_use_tracks_allocation() {
        let mut p = BlockPool::with_byte_budget(4 * 16 * 100, 16, 100);
        assert_eq!(p.bytes_per_token(), 100);
        assert_eq!(p.bytes_in_use(), 0);
        p.allocate(1, 20).unwrap(); // 2 blocks
        assert_eq!(p.bytes_in_use(), 2 * 16 * 100);
        p.release(1).unwrap();
        assert_eq!(p.bytes_in_use(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn aging_credits_reduce_bytes_and_clear_on_release() {
        let mut p = BlockPool::with_byte_budget(4 * 16 * 100, 16, 100);
        p.allocate(1, 16).unwrap(); // 1 block = 1600 accounting bytes
        p.allocate(2, 16).unwrap();
        assert_eq!(p.bytes_in_use(), 2 * 1600);
        p.credit_bytes(1, 600).unwrap();
        assert_eq!(p.credited_bytes(), 600);
        assert_eq!(p.bytes_in_use(), 2 * 1600 - 600);
        p.check_invariants().unwrap();
        // Credits accumulate but cap at the seq's accounting bytes.
        p.credit_bytes(1, 600).unwrap();
        p.credit_bytes(1, 9999).unwrap();
        assert_eq!(p.credited_bytes(), 1600);
        assert_eq!(p.bytes_in_use(), 1600);
        p.check_invariants().unwrap();
        // Unknown sequences are an error.
        assert!(p.credit_bytes(42, 1).is_err());
        // Release clears the credit along with the blocks.
        p.release(1).unwrap();
        assert_eq!(p.credited_bytes(), 0);
        assert_eq!(p.bytes_in_use(), 1600);
        p.release(2).unwrap();
        assert_eq!(p.bytes_in_use(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn truncate_shrinks_credit_to_surviving_blocks() {
        let mut p = BlockPool::with_byte_budget(4 * 16 * 100, 16, 100);
        p.allocate(1, 32).unwrap(); // 2 blocks
        p.credit_bytes(1, 2000).unwrap();
        assert_eq!(p.credited_bytes(), 2000);
        p.truncate(1, 16).unwrap(); // 1 block survives, cap now 1600
        assert_eq!(p.credited_bytes(), 1600);
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        assert_eq!(p.credited_bytes(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn seqkv_dispatch() {
        let sc = SlotCache::new(1, 1, 8, 32);
        let mut slot = sc.empty_slot();
        slot.pos = 3;
        let kv = SeqKv::F32(slot);
        assert_eq!(kv.pos(), 3);
        assert_eq!(kv.resident_bytes(), 2 * 8 * 32 * 4);
        assert!(kv.as_f32().is_some());

        let q = crate::kvquant::QuantSlotKv::new(
            crate::kvquant::KvQuantConfig::default(), 1, 1, 32);
        let kvq = SeqKv::Quant(q);
        assert_eq!(kvq.pos(), 0);
        assert_eq!(kvq.resident_bytes(), 0);
        assert!(kvq.as_f32().is_none());
    }

    #[test]
    fn seqkv_fork_variants() {
        // f32: deep copy — mutating the fork leaves the parent alone.
        let sc = SlotCache::new(1, 1, 8, 32);
        let mut slot = sc.empty_slot();
        slot.pos = 4;
        slot.k[0] = 7.0;
        let parent = SeqKv::F32(slot);
        let mut child = parent.fork();
        assert_eq!(child.pos(), 4);
        child.as_f32_mut().unwrap().k[0] = 9.0;
        assert_eq!(parent.as_f32().unwrap().k[0], 7.0);
        assert_eq!(parent.decoded_bytes(), 0);

        // quant: pages shared, position carried.
        let mut q = crate::kvquant::QuantSlotKv::new(
            crate::kvquant::KvQuantConfig {
                format: crate::kvquant::KvFormat::Dual,
                page_tokens: 8,
                policies: vec![crate::kvquant::KvPolicy { sink: 8, diag: 8 }],
            },
            1,
            1,
            32,
        );
        let rows: Vec<f32> = (0..12 * 32).map(|i| (i % 7) as f32 - 3.0).collect();
        q.k[0][0].append_rows(&rows);
        q.v[0][0].append_rows(&rows);
        q.pos = 12;
        let parent = SeqKv::Quant(q);
        let child = parent.fork();
        assert_eq!(child.pos(), 12);
        let (SeqKv::Quant(p), SeqKv::Quant(c)) = (&parent, &child) else {
            panic!("variant preserved")
        };
        assert!(std::sync::Arc::ptr_eq(p.k[0][0].page_arc(0), c.k[0][0].page_arc(0)));
    }

    #[test]
    fn slot_gather_scatter_round_trip() {
        let sc = SlotCache::new(2, 3, 8, 4);
        let mut s0 = sc.empty_slot();
        let mut s1 = sc.empty_slot();
        for (i, v) in s0.k.iter_mut().enumerate() {
            *v = i as f32;
        }
        for (i, v) in s1.k.iter_mut().enumerate() {
            *v = -(i as f32);
        }
        s0.v.copy_from_slice(&s0.k);
        s1.v.copy_from_slice(&s1.k);

        let b = 2;
        let mut bk = vec![0f32; sc.batch_elems(b)];
        let mut bv = vec![0f32; sc.batch_elems(b)];
        sc.gather_batch(&[Some(&s0), Some(&s1)], &mut bk, &mut bv);

        let mut r0 = sc.empty_slot();
        let mut r1 = sc.empty_slot();
        sc.scatter_batch(&bk, &bv, &mut [Some(&mut r0), Some(&mut r1)]);
        assert_eq!(r0.k, s0.k);
        assert_eq!(r1.k, s1.k);
        assert_eq!(r1.v, s1.v);
    }

    #[test]
    fn slot_from_prefill_pads() {
        let sc = SlotCache::new(1, 2, 8, 4);
        let l = 3;
        let n = 1 * 2 * l * 4;
        let kc: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let vc = kc.clone();
        let slot = sc.slot_from_prefill(&kc, &vc, l).unwrap();
        assert_eq!(slot.pos, 3);
        // Head 0 rows 0..3 copied, rows 3..8 zero.
        assert_eq!(slot.k[0], 0.0);
        assert_eq!(slot.k[3 * 4 - 1], 11.0);
        assert!(slot.k[3 * 4..8 * 4].iter().all(|&x| x == 0.0));
        // Head 1 starts at cache stride.
        assert_eq!(slot.k[8 * 4], 12.0);
    }

    #[test]
    fn gather_with_empty_slots_zeroes() {
        let sc = SlotCache::new(1, 1, 4, 2);
        let mut s0 = sc.empty_slot();
        s0.k.fill(5.0);
        s0.v.fill(6.0);
        let mut bk = vec![9f32; sc.batch_elems(2)];
        let mut bv = vec![9f32; sc.batch_elems(2)];
        sc.gather_batch(&[Some(&s0), None], &mut bk, &mut bv);
        assert!(bk[..8].iter().all(|&x| x == 5.0));
        assert!(bk[8..].iter().all(|&x| x == 0.0));
    }
}
