//! Serving telemetry: lock-free latency histograms, counters, rolling
//! 10 s gauges, per-request trace timelines, and Prometheus text
//! exposition.
//!
//! Everything on the record path is a handful of relaxed atomic ops —
//! no locks, no allocation — so instrumented code can call it from the
//! engine step loop and the per-layer decode fan-out without perturbing
//! the latencies being measured. The engine holds an
//! `Option<Arc<Telemetry>>`: `None` (the default, and what every bench
//! and library caller gets) keeps the pre-telemetry hot path
//! byte-for-byte, `Some` is what `dma serve` attaches so the server can
//! answer `{"cmd":"metrics"}` (see `benches/table14_telemetry_overhead`
//! for the overhead proof).
//!
//! Layout:
//! * [`Histogram`] — fixed log2-bucket latency histogram (µs domain).
//! * [`Counter`] — monotonic `u64`.
//! * [`RollingWindow`] — per-second ring for "last 10 s" gauges.
//! * [`Telemetry`] — the typed registry of everything above, plus the
//!   optional [`TraceSink`] and the sampled [`LayerProbe`].
//! * [`render_prometheus`] — text exposition (format version 0.0.4).
//! * [`TraceSink`] — Chrome `trace_event` JSONL writer (`--trace-out`;
//!   load the file with `chrome://tracing` or Perfetto).

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Number of log2 buckets. Bucket 0 holds exact zeros, bucket `i`
/// (1 <= i < BUCKETS-1) holds values in `[2^(i-1), 2^i - 1]` µs, and the
/// last bucket saturates (everything >= 2^(BUCKETS-2)). 40 buckets put
/// the saturation point at 2^38 µs ≈ 76 hours — far above any latency
/// this stack produces.
pub const BUCKETS: usize = 40;

/// Upper bound (inclusive, in µs) of bucket `i`. The saturating last
/// bucket has no finite bound; [`render_prometheus`] emits it as `+Inf`.
pub fn bucket_upper_us(i: usize) -> u64 {
    (1u64 << i) - 1
}

/// Bucket index for a recorded value in µs.
#[inline]
fn bucket_idx(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Lock-free fixed-bucket log-scale histogram over µs values.
///
/// `record` is three relaxed `fetch_add`s — no allocation, no locks, no
/// ordering constraints — so it is safe to call from any thread at any
/// rate. Reads take an O(BUCKETS) [`snapshot`](Self::snapshot); a
/// snapshot is not atomic across buckets, which only matters below
/// single-counter precision.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one value in µs.
    #[inline]
    pub fn record_us(&self, v: u64) {
        self.buckets[bucket_idx(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(v, Relaxed);
    }

    /// Record a duration given in milliseconds (the engine's native
    /// bookkeeping unit).
    #[inline]
    pub fn record_ms(&self, ms: f64) {
        self.record_us((ms * 1e3).max(0.0) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Relaxed)),
            count: self.count.load(Relaxed),
            sum_us: self.sum_us.load(Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`] with percentile readout.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum_us: u64,
}

impl HistogramSnapshot {
    /// Percentile upper bound in µs: the inclusive upper edge of the
    /// bucket containing the `q`-quantile sample (`q` in [0, 1]). The
    /// true sample value lies within a factor of 2 below the returned
    /// bound (exact for 0). Returns 0 for an empty histogram.
    pub fn percentile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return bucket_upper_us(i);
            }
        }
        bucket_upper_us(BUCKETS - 1)
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(0.50)
    }

    pub fn p90_us(&self) -> u64 {
        self.percentile_us(0.90)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(0.99)
    }

    /// Mean in µs (0 for an empty histogram).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }
}

/// Monotonic counter (relaxed atomic add).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Ring slots of the rolling window. 16 > 10 so a full 10 s read window
/// of per-second slots is always available while the current second is
/// still being written.
const WINDOW_SLOTS: u64 = 16;

/// Seconds summarised by the rolling gauges.
const WINDOW_SECS: u64 = 10;

/// Lock-free "last 10 seconds" accumulator: a ring of per-second slots
/// tagged with their absolute second. Writers CAS-claim the slot for the
/// current second (resetting a stale slot); readers sum the slots whose
/// tags fall inside the window. Claim races can drop a stray sample —
/// acceptable for a rolling gauge, never for the histograms (which is
/// why those are separate).
#[derive(Debug)]
pub struct RollingWindow {
    slots: [WindowSlot; WINDOW_SLOTS as usize],
}

#[derive(Debug, Default)]
struct WindowSlot {
    /// Absolute second this slot currently represents (+1, so that the
    /// zero-initialised state can never alias second 0).
    sec_tag: AtomicU64,
    sum: AtomicU64,
    n: AtomicU64,
}

impl Default for RollingWindow {
    fn default() -> RollingWindow {
        RollingWindow { slots: std::array::from_fn(|_| WindowSlot::default()) }
    }
}

impl RollingWindow {
    /// Add `v` to the slot for absolute second `sec`.
    pub fn add(&self, sec: u64, v: u64) {
        let slot = &self.slots[(sec % WINDOW_SLOTS) as usize];
        let tag = sec + 1;
        let cur = slot.sec_tag.load(Relaxed);
        if cur != tag {
            if slot.sec_tag.compare_exchange(cur, tag, Relaxed, Relaxed).is_ok() {
                slot.sum.store(0, Relaxed);
                slot.n.store(0, Relaxed);
            } else if slot.sec_tag.load(Relaxed) != tag {
                return; // lost the race to a different second; drop
            }
        }
        slot.sum.fetch_add(v, Relaxed);
        slot.n.fetch_add(1, Relaxed);
    }

    /// (sum, n) over the last [`WINDOW_SECS`] seconds ending at `now_sec`.
    pub fn totals(&self, now_sec: u64) -> (u64, u64) {
        let lo = now_sec.saturating_sub(WINDOW_SECS - 1) + 1;
        let (mut sum, mut n) = (0u64, 0u64);
        for slot in &self.slots {
            let tag = slot.sec_tag.load(Relaxed);
            if tag >= lo && tag <= now_sec + 1 {
                sum += slot.sum.load(Relaxed);
                n += slot.n.load(Relaxed);
            }
        }
        (sum, n)
    }

    /// Sum over the window divided by the window length in seconds.
    pub fn rate_per_sec(&self, now_sec: u64) -> f64 {
        self.totals(now_sec).0 as f64 / WINDOW_SECS as f64
    }

    /// Mean of the recorded values over the window (0 when empty).
    pub fn mean(&self, now_sec: u64) -> f64 {
        let (sum, n) = self.totals(now_sec);
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }
}

/// Sampled per-layer timing probe for the model's decode hot path
/// (`--metrics-sample-n`). One decode step in `sample_every` is timed:
/// per-layer attention (dequant-inclusive on the quantized-cache path)
/// and per-layer KV quantize-on-append. `sample_every == 0` disables the
/// probe; the model then pays one relaxed load per decode step and
/// nothing per layer.
#[derive(Debug)]
pub struct LayerProbe {
    sample_every: u64,
    ctr: AtomicU64,
    pub attn_us: Histogram,
    pub kv_append_us: Histogram,
}

impl LayerProbe {
    pub fn new(sample_every: u64) -> LayerProbe {
        LayerProbe {
            sample_every,
            ctr: AtomicU64::new(0),
            attn_us: Histogram::new(),
            kv_append_us: Histogram::new(),
        }
    }

    pub fn disabled() -> LayerProbe {
        LayerProbe::new(0)
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }

    /// Decide once per decode step whether this step's layers are timed.
    #[inline]
    pub fn should_sample(&self) -> bool {
        self.sample_every != 0 && self.ctr.fetch_add(1, Relaxed) % self.sample_every == 0
    }
}

/// Chrome `trace_event` JSONL sink (`--trace-out`). Each line is one
/// complete-span (`"ph":"X"`) or instant (`"ph":"i"`) event; wrap the
/// lines in `[...]` (or load the JSONL directly into Perfetto) to view.
/// `pid` is the worker index, `tid` the request id, timestamps are µs
/// since sink creation. Writes take a mutex around a buffered writer;
/// spans stay buffered and reach disk on the next instant event
/// (request finish/cancel) or when the sink drops. Tracing is
/// explicitly opt-in and not on the zero-overhead path.
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    w: Mutex<BufWriter<File>>,
}

impl TraceSink {
    pub fn create(path: &Path) -> std::io::Result<TraceSink> {
        Ok(TraceSink {
            epoch: Instant::now(),
            w: Mutex::new(BufWriter::new(File::create(path)?)),
        })
    }

    /// Microseconds since sink creation (the trace timebase).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Emit a complete span: `[ts_us, ts_us + dur_us]` on row
    /// (pid=worker, tid=request).
    pub fn span(
        &self,
        name: &str,
        worker: usize,
        request: u64,
        ts_us: u64,
        dur_us: u64,
        args: &[(&str, f64)],
    ) {
        self.write_event(name, "X", worker, request, ts_us, Some(dur_us), args);
    }

    /// Emit an instant event at `ts_us`.
    pub fn instant(
        &self,
        name: &str,
        worker: usize,
        request: u64,
        ts_us: u64,
        args: &[(&str, f64)],
    ) {
        self.write_event(name, "i", worker, request, ts_us, None, args);
    }

    fn write_event(
        &self,
        name: &str,
        ph: &str,
        worker: usize,
        request: u64,
        ts_us: u64,
        dur_us: Option<u64>,
        args: &[(&str, f64)],
    ) {
        let mut line = format!(
            "{{\"name\":\"{name}\",\"ph\":\"{ph}\",\"ts\":{ts_us},\"pid\":{worker},\"tid\":{request}"
        );
        if let Some(d) = dur_us {
            line += &format!(",\"dur\":{d}");
        }
        if ph == "i" {
            line += ",\"s\":\"t\"";
        }
        if !args.is_empty() {
            line += ",\"args\":{";
            for (i, (k, v)) in args.iter().enumerate() {
                if i > 0 {
                    line += ",";
                }
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    line += &format!("\"{k}\":{}", *v as i64);
                } else {
                    line += &format!("\"{k}\":{v}");
                }
            }
            line += "}";
        }
        line += "}\n";
        let mut w = self.w.lock().unwrap();
        let _ = w.write_all(line.as_bytes());
        // Spans stay buffered (one flush per span would syscall on every
        // decode step); instants mark request-level milestones
        // (finish/cancel), so flushing there bounds loss on an unclean
        // exit to the in-flight requests' spans.
        if ph == "i" {
            let _ = w.flush();
        }
    }
}

/// The serving stack's telemetry registry: typed histograms, counters
/// and rolling gauges, plus the optional trace sink and the sampled
/// layer probe. One instance is shared (`Arc`) across every engine
/// worker, so histograms and counters aggregate fleet-wide; per-worker
/// gauges (queue depth, KV pressure) stay on each `EngineHandle`'s
/// published atomics and are joined in at render time.
#[derive(Debug)]
pub struct Telemetry {
    epoch: Instant,
    // -- latency histograms (µs domain) --------------------------------
    /// Queue-entry to admission.
    pub queue_us: Histogram,
    /// Queue-entry to first generated token (per request group).
    pub ttft_us: Histogram,
    /// Decode-step wall time amortised per generated token.
    pub inter_token_us: Histogram,
    /// One batched decode step.
    pub decode_step_us: Histogram,
    /// One prefill chunk.
    pub prefill_chunk_us: Histogram,
    /// Engine step phase: admission sweep.
    pub step_admit_us: Histogram,
    /// Engine step phase: prefill sweep.
    pub step_prefill_us: Histogram,
    /// Engine step phase: decode slice.
    pub step_decode_us: Histogram,
    /// Router event fan-in: one `poll_events` drain that yielded events.
    pub fanin_us: Histogram,
    // -- admission / progress counters ----------------------------------
    pub requests_submitted: Counter,
    pub requests_admitted: Counter,
    pub requests_completed: Counter,
    pub requests_cancelled: Counter,
    /// Rejections because the group cannot ever fit the pool's blocks.
    pub rejected_blocks: Counter,
    /// Rejections because the group cannot ever fit the byte budget.
    pub rejected_bytes: Counter,
    /// Rejections for non-capacity reasons (queue full, bad params...).
    pub rejected_other: Counter,
    /// Admission deferrals (request stays queued) split by which budget
    /// clause failed this step.
    pub deferred_blocks: Counter,
    pub deferred_bytes: Counter,
    pub prefill_tokens: Counter,
    pub decode_tokens: Counter,
    pub prefix_hit_tokens: Counter,
    /// Individual candidates cancelled out of a still-running group
    /// (whole-group cancels count once in `requests_cancelled`).
    pub candidates_cancelled: Counter,
    // -- resilience ------------------------------------------------------
    /// Engine workers respawned by the router's supervisor.
    pub worker_restarts: Counter,
    /// In-flight/queued groups re-dispatched after a worker death.
    pub requests_replayed: Counter,
    /// Submissions shed under KV pressure (`--shed-policy`).
    pub requests_shed: Counter,
    /// Deadline cancellations, split by which bound fired
    /// (`dma_deadline_cancels_total{cause=...}`).
    pub deadline_cancels_request: Counter,
    pub deadline_cancels_queue: Counter,
    pub deadline_cancels_deadline: Counter,
    // -- speculative decoding ([`crate::spec`]) -------------------------
    /// Draft tokens proposed for verification.
    pub spec_proposed_tokens: Counter,
    /// Draft tokens accepted by verification.
    pub spec_accepted_tokens: Counter,
    /// Drafted positions rolled back out of the KV cache.
    pub spec_rolled_back_tokens: Counter,
    /// Tokens emitted per speculative round (accepted drafts plus the
    /// correction/bonus token) — a token-count histogram, not a latency.
    pub spec_tokens_per_round: Histogram,
    // -- tiered KV memory (`--kv-spill`) --------------------------------
    /// Bytes written to the workers' spill files (cold-tier writes).
    pub kv_spill_bytes: Counter,
    /// Bytes read back from spill files on spilled-prefix reloads.
    pub kv_reload_bytes: Counter,
    /// Radix pages precision-aged (MXFP8 planes dropped, bytes credited
    /// back to the pool).
    pub kv_pages_aged: Counter,
    /// One spilled-prefix reload sweep (disk read + parallel decode).
    pub kv_reload_us: Histogram,
    // -- rolling 10 s gauges --------------------------------------------
    /// Generated tokens; read as tokens/s over the window.
    pub tokens_10s: RollingWindow,
    /// TTFT samples in µs; read as a rolling mean.
    pub ttft_10s: RollingWindow,
    // -- opt-in extras --------------------------------------------------
    trace: Option<TraceSink>,
    probe: Arc<LayerProbe>,
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new()
    }
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry {
            epoch: Instant::now(),
            queue_us: Histogram::new(),
            ttft_us: Histogram::new(),
            inter_token_us: Histogram::new(),
            decode_step_us: Histogram::new(),
            prefill_chunk_us: Histogram::new(),
            step_admit_us: Histogram::new(),
            step_prefill_us: Histogram::new(),
            step_decode_us: Histogram::new(),
            fanin_us: Histogram::new(),
            requests_submitted: Counter::default(),
            requests_admitted: Counter::default(),
            requests_completed: Counter::default(),
            requests_cancelled: Counter::default(),
            rejected_blocks: Counter::default(),
            rejected_bytes: Counter::default(),
            rejected_other: Counter::default(),
            deferred_blocks: Counter::default(),
            deferred_bytes: Counter::default(),
            prefill_tokens: Counter::default(),
            decode_tokens: Counter::default(),
            prefix_hit_tokens: Counter::default(),
            candidates_cancelled: Counter::default(),
            worker_restarts: Counter::default(),
            requests_replayed: Counter::default(),
            requests_shed: Counter::default(),
            deadline_cancels_request: Counter::default(),
            deadline_cancels_queue: Counter::default(),
            deadline_cancels_deadline: Counter::default(),
            spec_proposed_tokens: Counter::default(),
            spec_accepted_tokens: Counter::default(),
            spec_rolled_back_tokens: Counter::default(),
            spec_tokens_per_round: Histogram::new(),
            kv_spill_bytes: Counter::default(),
            kv_reload_bytes: Counter::default(),
            kv_pages_aged: Counter::default(),
            kv_reload_us: Histogram::new(),
            tokens_10s: RollingWindow::default(),
            ttft_10s: RollingWindow::default(),
            trace: None,
            probe: Arc::new(LayerProbe::disabled()),
        }
    }

    /// Attach a Chrome trace_event sink (`--trace-out`).
    pub fn with_trace(mut self, sink: TraceSink) -> Telemetry {
        self.trace = Some(sink);
        self
    }

    /// Attach a per-layer sampling probe (`--metrics-sample-n`).
    pub fn with_probe(mut self, sample_every: u64) -> Telemetry {
        self.probe = Arc::new(LayerProbe::new(sample_every));
        self
    }

    pub fn trace(&self) -> Option<&TraceSink> {
        self.trace.as_ref()
    }

    pub fn probe(&self) -> &Arc<LayerProbe> {
        &self.probe
    }

    /// Absolute second on the telemetry clock (rolling-window key).
    pub fn now_sec(&self) -> u64 {
        self.epoch.elapsed().as_secs()
    }
}

/// Per-worker gauge snapshot joined into the Prometheus render; built by
/// `Router::worker_gauges` from each `EngineHandle`'s published atomics.
#[derive(Debug, Clone, Copy)]
pub struct WorkerGauges {
    pub queue_depth: u64,
    pub kv_bytes_in_use: u64,
    pub kv_bytes_capacity: u64,
    pub decoded_bytes_live: u64,
    /// Tier residency (`--kv-spill`): prefix-cache pages holding every
    /// plane, pages aged down to their low copy, pages on disk, and the
    /// spill-file bytes holding them. All 0 with the tier off.
    pub tier_hot_pages: u64,
    pub tier_aged_pages: u64,
    pub tier_spilled_pages: u64,
    pub tier_spilled_bytes: u64,
    /// Worker thread alive (cleared on panic/exit until the supervisor
    /// respawns it).
    pub healthy: bool,
}

impl Default for WorkerGauges {
    fn default() -> WorkerGauges {
        WorkerGauges {
            queue_depth: 0,
            kv_bytes_in_use: 0,
            kv_bytes_capacity: 0,
            decoded_bytes_live: 0,
            tier_hot_pages: 0,
            tier_aged_pages: 0,
            tier_spilled_pages: 0,
            tier_spilled_bytes: 0,
            healthy: true,
        }
    }
}

impl WorkerGauges {
    /// KV byte-budget pressure in [0, 1] (decoded-page bytes charge the
    /// same budget as the paged stores, matching engine admission).
    pub fn kv_pressure(&self) -> f64 {
        if self.kv_bytes_capacity == 0 {
            0.0
        } else {
            (self.kv_bytes_in_use + self.decoded_bytes_live) as f64
                / self.kv_bytes_capacity as f64
        }
    }
}

fn render_histogram(out: &mut String, name: &str, help: &str, s: &HistogramSnapshot) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let mut cum = 0u64;
    for i in 0..BUCKETS {
        cum += s.buckets[i];
        if i == BUCKETS - 1 {
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
        } else {
            // Inclusive integer-µs upper bound, exposed in seconds.
            let le = bucket_upper_us(i) as f64 / 1e6;
            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
        }
    }
    out.push_str(&format!("{name}_sum {}\n", s.sum_us as f64 / 1e6));
    out.push_str(&format!("{name}_count {}\n", s.count));
}

/// Histogram render for counting (unitless) domains: bucket edges are
/// the raw recorded integers, not µs-to-seconds conversions — used for
/// the tokens-per-round speculation histogram.
fn render_histogram_counts(out: &mut String, name: &str, help: &str, s: &HistogramSnapshot) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let mut cum = 0u64;
    for i in 0..BUCKETS {
        cum += s.buckets[i];
        if i == BUCKETS - 1 {
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
        } else {
            out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", bucket_upper_us(i)));
        }
    }
    out.push_str(&format!("{name}_sum {}\n", s.sum_us));
    out.push_str(&format!("{name}_count {}\n", s.count));
}

fn render_counter(out: &mut String, name: &str, help: &str, v: u64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
    ));
}

fn render_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
    ));
}

/// Render the full metric surface in Prometheus text exposition format.
/// `workers` carries the per-worker gauges (index = worker label);
/// `pages` is the fleet-wide page-decode snapshot
/// ([`crate::metrics::KvPageStats`], `Router::kv_page_stats`).
pub fn render_prometheus(
    t: &Telemetry,
    workers: &[WorkerGauges],
    pages: &crate::metrics::KvPageStats,
) -> String {
    let mut out = String::with_capacity(8192);

    render_histogram(
        &mut out,
        "dma_ttft_seconds",
        "Time from enqueue to first generated token",
        &t.ttft_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_inter_token_seconds",
        "Decode-step wall time amortised per generated token",
        &t.inter_token_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_decode_step_seconds",
        "Batched decode step wall time",
        &t.decode_step_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_prefill_chunk_seconds",
        "Prefill chunk wall time",
        &t.prefill_chunk_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_queue_seconds",
        "Time from enqueue to admission",
        &t.queue_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_step_admit_seconds",
        "Engine step admission-phase wall time",
        &t.step_admit_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_step_prefill_seconds",
        "Engine step prefill-phase wall time",
        &t.step_prefill_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_step_decode_seconds",
        "Engine step decode-phase wall time",
        &t.step_decode_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_router_fanin_seconds",
        "Router event fan-in drain wall time",
        &t.fanin_us.snapshot(),
    );
    render_histogram(
        &mut out,
        "dma_pool_wait_seconds",
        "Worker-pool job enqueue-to-dequeue wall time",
        &crate::util::pool::wait_histogram().snapshot(),
    );
    // Tier families render unconditionally (all-zero with --kv-spill
    // off) so scrapes never see the series appear late.
    render_histogram(
        &mut out,
        "dma_kv_reload_seconds",
        "Spilled-prefix reload sweep wall time (disk read + parallel decode)",
        &t.kv_reload_us.snapshot(),
    );
    let probe = t.probe();
    if probe.sample_every() > 0 {
        render_histogram(
            &mut out,
            "dma_layer_attn_seconds",
            "Sampled per-layer decode attention wall time",
            &probe.attn_us.snapshot(),
        );
        render_histogram(
            &mut out,
            "dma_layer_kv_append_seconds",
            "Sampled per-layer KV quantize-on-append wall time",
            &probe.kv_append_us.snapshot(),
        );
    }

    render_counter(
        &mut out,
        "dma_requests_submitted_total",
        "Requests accepted into the queue",
        t.requests_submitted.get(),
    );
    render_counter(
        &mut out,
        "dma_requests_admitted_total",
        "Requests admitted to prefill",
        t.requests_admitted.get(),
    );
    render_counter(
        &mut out,
        "dma_requests_completed_total",
        "Requests finished with a terminal response",
        t.requests_completed.get(),
    );
    render_counter(
        &mut out,
        "dma_requests_cancelled_total",
        "Requests cancelled before completion",
        t.requests_cancelled.get(),
    );
    out.push_str(concat!(
        "# HELP dma_requests_rejected_total Requests rejected at submit, by cause\n",
        "# TYPE dma_requests_rejected_total counter\n"
    ));
    out.push_str(&format!(
        "dma_requests_rejected_total{{cause=\"blocks\"}} {}\n",
        t.rejected_blocks.get()
    ));
    out.push_str(&format!(
        "dma_requests_rejected_total{{cause=\"bytes\"}} {}\n",
        t.rejected_bytes.get()
    ));
    out.push_str(&format!(
        "dma_requests_rejected_total{{cause=\"other\"}} {}\n",
        t.rejected_other.get()
    ));
    out.push_str(concat!(
        "# HELP dma_admission_deferred_total Admission attempts deferred, by failing budget\n",
        "# TYPE dma_admission_deferred_total counter\n"
    ));
    out.push_str(&format!(
        "dma_admission_deferred_total{{cause=\"blocks\"}} {}\n",
        t.deferred_blocks.get()
    ));
    out.push_str(&format!(
        "dma_admission_deferred_total{{cause=\"bytes\"}} {}\n",
        t.deferred_bytes.get()
    ));
    render_counter(
        &mut out,
        "dma_prefill_tokens_total",
        "Prompt tokens prefilled (including prefix-cache hits)",
        t.prefill_tokens.get(),
    );
    render_counter(
        &mut out,
        "dma_decode_tokens_total",
        "Tokens generated by decode",
        t.decode_tokens.get(),
    );
    render_counter(
        &mut out,
        "dma_prefix_hit_tokens_total",
        "Prompt tokens served from the prefix cache",
        t.prefix_hit_tokens.get(),
    );
    render_counter(
        &mut out,
        "dma_candidates_cancelled_total",
        "Individual candidates cancelled out of still-running groups",
        t.candidates_cancelled.get(),
    );
    // Resilience families render unconditionally (all-zero in a healthy
    // fleet) so dashboards can alert on their first increment.
    render_counter(
        &mut out,
        "dma_worker_restarts_total",
        "Engine workers respawned by the router's supervisor",
        t.worker_restarts.get(),
    );
    render_counter(
        &mut out,
        "dma_requests_replayed_total",
        "Groups re-dispatched onto a fresh engine after a worker death",
        t.requests_replayed.get(),
    );
    render_counter(
        &mut out,
        "dma_requests_shed_total",
        "Submissions shed under KV pressure (--shed-policy)",
        t.requests_shed.get(),
    );
    out.push_str(concat!(
        "# HELP dma_deadline_cancels_total Requests cancelled at a deadline, by which bound fired\n",
        "# TYPE dma_deadline_cancels_total counter\n"
    ));
    out.push_str(&format!(
        "dma_deadline_cancels_total{{cause=\"request\"}} {}\n",
        t.deadline_cancels_request.get()
    ));
    out.push_str(&format!(
        "dma_deadline_cancels_total{{cause=\"queue\"}} {}\n",
        t.deadline_cancels_queue.get()
    ));
    out.push_str(&format!(
        "dma_deadline_cancels_total{{cause=\"deadline\"}} {}\n",
        t.deadline_cancels_deadline.get()
    ));
    // Speculation families render unconditionally (all-zero when --spec
    // off) so scrapes and dashboards never see the series appear late.
    render_histogram_counts(
        &mut out,
        "dma_spec_accepted_tokens",
        "Tokens emitted per speculative round (accepted drafts + correction/bonus)",
        &t.spec_tokens_per_round.snapshot(),
    );
    render_counter(
        &mut out,
        "dma_spec_proposed_tokens_total",
        "Draft tokens proposed for speculative verification",
        t.spec_proposed_tokens.get(),
    );
    render_counter(
        &mut out,
        "dma_spec_accepted_tokens_total",
        "Draft tokens accepted by speculative verification",
        t.spec_accepted_tokens.get(),
    );
    render_counter(
        &mut out,
        "dma_spec_rolled_back_tokens_total",
        "Drafted positions rolled back out of the KV cache",
        t.spec_rolled_back_tokens.get(),
    );
    out.push_str(concat!(
        "# HELP dma_kv_pages_decoded_total Quantized KV pages decoded, by tile precision\n",
        "# TYPE dma_kv_pages_decoded_total counter\n"
    ));
    out.push_str(&format!(
        "dma_kv_pages_decoded_total{{precision=\"high\"}} {}\n",
        pages.high_pages
    ));
    out.push_str(&format!(
        "dma_kv_pages_decoded_total{{precision=\"low\"}} {}\n",
        pages.low_pages
    ));
    render_counter(
        &mut out,
        "dma_decoded_page_hits_total",
        "Decoded-page cache hits",
        pages.cache_hits,
    );
    render_counter(
        &mut out,
        "dma_decoded_page_misses_total",
        "Decoded-page cache misses",
        pages.cache_misses,
    );
    render_counter(
        &mut out,
        "dma_decoded_page_evictions_total",
        "Decoded-page cache evictions",
        pages.cache_evictions,
    );
    render_counter(
        &mut out,
        "dma_kv_spill_bytes_total",
        "Bytes written to the workers' KV spill files",
        t.kv_spill_bytes.get(),
    );
    render_counter(
        &mut out,
        "dma_kv_reload_bytes_total",
        "Bytes read back from KV spill files on prefix reloads",
        t.kv_reload_bytes.get(),
    );
    render_counter(
        &mut out,
        "dma_kv_pages_aged_total",
        "Prefix-cache pages precision-aged to their low copy",
        t.kv_pages_aged.get(),
    );

    let now = t.now_sec();
    render_gauge(
        &mut out,
        "dma_tokens_per_second_10s",
        "Generated tokens per second over the last 10 s",
        t.tokens_10s.rate_per_sec(now),
    );
    render_gauge(
        &mut out,
        "dma_ttft_ms_10s",
        "Mean TTFT in ms over the last 10 s",
        t.ttft_10s.mean(now) / 1e3,
    );
    // Fleet-wide tier residency, summed from the per-worker snapshots.
    let tier = workers.iter().fold((0u64, 0u64, 0u64, 0u64), |a, w| {
        (
            a.0 + w.tier_hot_pages,
            a.1 + w.tier_aged_pages,
            a.2 + w.tier_spilled_pages,
            a.3 + w.tier_spilled_bytes,
        )
    });
    out.push_str(concat!(
        "# HELP dma_kv_tier_pages Prefix-cache pages resident per KV tier, fleet-wide\n",
        "# TYPE dma_kv_tier_pages gauge\n"
    ));
    out.push_str(&format!("dma_kv_tier_pages{{tier=\"hot\"}} {}\n", tier.0));
    out.push_str(&format!("dma_kv_tier_pages{{tier=\"aged\"}} {}\n", tier.1));
    out.push_str(&format!("dma_kv_tier_pages{{tier=\"spilled\"}} {}\n", tier.2));
    render_gauge(
        &mut out,
        "dma_kv_spilled_bytes",
        "Spill-file bytes holding live cold pages, fleet-wide",
        tier.3 as f64,
    );

    fn per_worker(
        out: &mut String,
        name: &str,
        help: &str,
        workers: &[WorkerGauges],
        get: impl Fn(&WorkerGauges) -> f64,
    ) {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
        for (i, w) in workers.iter().enumerate() {
            out.push_str(&format!("{name}{{worker=\"{i}\"}} {}\n", get(w)));
        }
    }
    per_worker(
        &mut out,
        "dma_worker_queue_depth",
        "In-flight requests owned by the worker",
        workers,
        |w| w.queue_depth as f64,
    );
    per_worker(
        &mut out,
        "dma_worker_kv_bytes_in_use",
        "KV cache bytes resident on the worker",
        workers,
        |w| w.kv_bytes_in_use as f64,
    );
    per_worker(
        &mut out,
        "dma_worker_kv_bytes_capacity",
        "KV cache byte budget of the worker",
        workers,
        |w| w.kv_bytes_capacity as f64,
    );
    per_worker(
        &mut out,
        "dma_worker_decoded_bytes_live",
        "Decoded-page cache bytes charged against the worker budget",
        workers,
        |w| w.decoded_bytes_live as f64,
    );
    per_worker(
        &mut out,
        "dma_worker_kv_pressure",
        "KV byte-budget utilisation in [0,1]",
        workers,
        |w| w.kv_pressure(),
    );
    per_worker(
        &mut out,
        "dma_worker_healthy",
        "1 while the worker thread is alive, 0 between death and respawn",
        workers,
        |w| if w.healthy { 1.0 } else { 0.0 },
    );

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_idx(0), 0);
        assert_eq!(bucket_idx(1), 1);
        assert_eq!(bucket_idx(2), 2);
        assert_eq!(bucket_idx(3), 2);
        assert_eq!(bucket_idx(4), 3);
        assert_eq!(bucket_idx(7), 3);
        assert_eq!(bucket_idx(8), 4);
        // Every bucket's inclusive edges map to itself.
        for i in 1..BUCKETS - 1 {
            assert_eq!(bucket_idx(1u64 << (i - 1)), i, "lower edge of bucket {i}");
            assert_eq!(bucket_idx((1u64 << i) - 1), i, "upper edge of bucket {i}");
        }
        // The last bucket saturates.
        assert_eq!(bucket_idx(1u64 << (BUCKETS - 1)), BUCKETS - 1);
        assert_eq!(bucket_idx(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn overflow_bucket_saturates() {
        let h = Histogram::new();
        h.record_us(u64::MAX);
        h.record_us(1u64 << 50);
        let s = h.snapshot();
        assert_eq!(s.buckets[BUCKETS - 1], 2);
        assert_eq!(s.count, 2);
        // p99 lands in the saturating bucket and reports its sentinel
        // upper bound rather than wrapping.
        assert_eq!(s.percentile_us(0.99), bucket_upper_us(BUCKETS - 1));
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.percentile_us(0.5), 0);
        assert_eq!(s.mean_us(), 0.0);
    }

    /// Histogram percentiles vs a sorted-vec oracle: the reported bucket
    /// upper bound must bracket the exact sample percentile from below
    /// within one bucket (factor of 2).
    #[test]
    fn percentile_matches_sorted_oracle() {
        prop::check("histogram percentile oracle", 25, |rng| {
            let n = rng.int_in(1, 400) as usize;
            let h = Histogram::new();
            let mut vals: Vec<u64> = (0..n)
                .map(|_| {
                    // Span many decades, including zeros.
                    let mag = rng.int_in(0, 20) as u32;
                    (rng.uniform() * f64::from(1u32 << mag)) as u64
                })
                .collect();
            for &v in &vals {
                h.record_us(v);
            }
            vals.sort_unstable();
            let s = h.snapshot();
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let oracle = vals[rank - 1];
                let got = s.percentile_us(q);
                prop_assert!(
                    got >= oracle,
                    "p{q}: bucket bound {got} below oracle {oracle}"
                );
                // One log2 bucket of slack: bound < 2 * max(oracle, 1).
                prop_assert!(
                    got < 2 * oracle.max(1) || got == 0,
                    "p{q}: bucket bound {got} too far above oracle {oracle}"
                );
            }
            prop_assert!(s.count == n as u64);
            Ok(())
        });
    }

    /// Concurrent recording loses no samples and lands every value in
    /// its correct bucket.
    #[test]
    fn concurrent_recording_is_lossless() {
        let h = std::sync::Arc::new(Histogram::new());
        let threads = 8;
        let per = 5000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        // Deterministic spread across buckets per thread.
                        h.record_us((i + t) % 1024);
                    }
                })
            })
            .collect();
        for jh in handles {
            jh.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, threads * per);
        assert_eq!(s.buckets.iter().sum::<u64>(), threads * per);
        // Cross-check against a serially-built reference histogram.
        let reference = Histogram::new();
        for t in 0..threads {
            for i in 0..per {
                reference.record_us((i + t) % 1024);
            }
        }
        let r = reference.snapshot();
        assert_eq!(s.buckets, r.buckets);
        assert_eq!(s.sum_us, r.sum_us);
    }

    #[test]
    fn rolling_window_drops_stale_seconds() {
        let w = RollingWindow::default();
        w.add(100, 50);
        w.add(105, 30);
        let (sum, n) = w.totals(105);
        assert_eq!((sum, n), (80, 2));
        assert_eq!(w.rate_per_sec(105), 8.0);
        assert_eq!(w.mean(105), 40.0);
        // 30 s later both slots are outside the window.
        let (sum, n) = w.totals(135);
        assert_eq!((sum, n), (0, 0));
        // Ring reuse: second 116 lands on slot 100 % 16 and evicts it.
        w.add(116, 7);
        let (sum, _) = w.totals(120);
        assert_eq!(sum, 7);
    }

    #[test]
    fn rolling_window_second_zero_is_counted() {
        let w = RollingWindow::default();
        w.add(0, 5);
        assert_eq!(w.totals(0), (5, 1));
    }

    #[test]
    fn layer_probe_sampling_cadence() {
        let p = LayerProbe::new(4);
        let hits: Vec<bool> = (0..8).map(|_| p.should_sample()).collect();
        assert_eq!(hits, vec![true, false, false, false, true, false, false, false]);
        let off = LayerProbe::disabled();
        assert!(!(0..8).any(|_| off.should_sample()));
    }

    #[test]
    fn prometheus_render_has_required_families() {
        let t = Telemetry::new();
        t.ttft_us.record_ms(12.5);
        t.inter_token_us.record_us(800);
        t.decode_step_us.record_us(3200);
        t.rejected_blocks.inc();
        t.requests_completed.inc();
        t.spec_proposed_tokens.add(6);
        t.spec_accepted_tokens.add(4);
        t.spec_rolled_back_tokens.add(2);
        t.spec_tokens_per_round.record_us(3);
        t.candidates_cancelled.inc();
        t.worker_restarts.inc();
        t.requests_replayed.add(2);
        t.requests_shed.add(3);
        t.deadline_cancels_queue.inc();
        t.kv_spill_bytes.add(4096);
        t.kv_reload_bytes.add(2048);
        t.kv_pages_aged.add(7);
        t.kv_reload_us.record_us(150);
        let workers = [
            WorkerGauges {
                queue_depth: 2,
                kv_bytes_in_use: 1000,
                kv_bytes_capacity: 4000,
                decoded_bytes_live: 200,
                tier_hot_pages: 10,
                tier_aged_pages: 4,
                tier_spilled_pages: 6,
                tier_spilled_bytes: 3000,
                healthy: true,
            },
            WorkerGauges { tier_hot_pages: 1, tier_spilled_bytes: 500, healthy: false, ..Default::default() },
        ];
        let pages = crate::metrics::KvPageStats {
            high_pages: 3,
            low_pages: 9,
            cache_hits: 5,
            cache_misses: 2,
            cache_evictions: 1,
        };
        let text = render_prometheus(&t, &workers, &pages);
        for family in [
            "dma_ttft_seconds_bucket",
            "dma_ttft_seconds_count 1",
            "dma_inter_token_seconds_bucket",
            "dma_decode_step_seconds_bucket",
            "dma_pool_wait_seconds_bucket",
            "dma_requests_rejected_total{cause=\"blocks\"} 1",
            "dma_requests_completed_total 1",
            "dma_admission_deferred_total{cause=\"bytes\"} 0",
            "dma_worker_queue_depth{worker=\"0\"} 2",
            "dma_worker_queue_depth{worker=\"1\"} 0",
            "dma_worker_kv_pressure{worker=\"0\"} 0.3",
            "dma_tokens_per_second_10s",
            "dma_ttft_ms_10s",
            "dma_kv_pages_decoded_total{precision=\"high\"} 3",
            "dma_kv_pages_decoded_total{precision=\"low\"} 9",
            "dma_decoded_page_hits_total 5",
            "dma_decoded_page_misses_total 2",
            "dma_decoded_page_evictions_total 1",
            "dma_spec_proposed_tokens_total 6",
            "dma_spec_accepted_tokens_total 4",
            "dma_spec_rolled_back_tokens_total 2",
            "dma_spec_accepted_tokens_count 1",
            "dma_candidates_cancelled_total 1",
            "dma_worker_restarts_total 1",
            "dma_requests_replayed_total 2",
            "dma_requests_shed_total 3",
            "dma_deadline_cancels_total{cause=\"request\"} 0",
            "dma_deadline_cancels_total{cause=\"queue\"} 1",
            "dma_deadline_cancels_total{cause=\"deadline\"} 0",
            "dma_worker_healthy{worker=\"0\"} 1",
            "dma_worker_healthy{worker=\"1\"} 0",
            "dma_kv_spill_bytes_total 4096",
            "dma_kv_reload_bytes_total 2048",
            "dma_kv_pages_aged_total 7",
            "dma_kv_reload_seconds_count 1",
            "dma_kv_tier_pages{tier=\"hot\"} 11",
            "dma_kv_tier_pages{tier=\"aged\"} 4",
            "dma_kv_tier_pages{tier=\"spilled\"} 6",
            "dma_kv_spilled_bytes 3500",
            "le=\"+Inf\"",
        ] {
            assert!(text.contains(family), "missing '{family}' in:\n{text}");
        }
        // Every histogram line set is cumulative and ends at count.
        assert!(text.contains("dma_ttft_seconds_sum 0.0125"));
        // The token-count histogram renders raw (unitless) bucket edges
        // and sum: a 3-token round lands under le="3", not le-in-seconds.
        assert!(text.contains("dma_spec_accepted_tokens_bucket{le=\"3\"} 1"), "{text}");
        assert!(text.contains("dma_spec_accepted_tokens_sum 3"));

        // All-zero speculation families still render with --spec off.
        let cold = render_prometheus(&Telemetry::new(), &[], &pages);
        for family in [
            "# TYPE dma_spec_accepted_tokens histogram",
            "# TYPE dma_spec_proposed_tokens_total counter",
            "# TYPE dma_spec_rolled_back_tokens_total counter",
            "# TYPE dma_candidates_cancelled_total counter",
            "# TYPE dma_worker_restarts_total counter",
            "# TYPE dma_requests_replayed_total counter",
            "# TYPE dma_requests_shed_total counter",
            "# TYPE dma_deadline_cancels_total counter",
            "# TYPE dma_worker_healthy gauge",
            "# TYPE dma_kv_spill_bytes_total counter",
            "# TYPE dma_kv_reload_bytes_total counter",
            "# TYPE dma_kv_pages_aged_total counter",
            "# TYPE dma_kv_reload_seconds histogram",
            "# TYPE dma_kv_tier_pages gauge",
            "# TYPE dma_kv_spilled_bytes gauge",
        ] {
            assert!(cold.contains(family), "missing '{family}'");
        }
    }

    #[test]
    fn worker_gauges_pressure() {
        let w = WorkerGauges {
            kv_bytes_in_use: 750,
            kv_bytes_capacity: 1000,
            decoded_bytes_live: 250,
            ..Default::default()
        };
        assert_eq!(w.kv_pressure(), 1.0);
        assert_eq!(WorkerGauges::default().kv_pressure(), 0.0);
    }

    #[test]
    fn trace_sink_writes_chrome_trace_events() {
        let dir = std::env::temp_dir().join("dma_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("trace_{}.jsonl", std::process::id()));
        let sink = TraceSink::create(&path).unwrap();
        sink.span("decode_step", 0, 7, 100, 250, &[("batch", 3.0), ("ms", 0.25)]);
        sink.instant("finish", 1, 7, 400, &[]);
        drop(sink);
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 2);
        let ev = crate::util::json::Json::parse(lines[0]).unwrap();
        assert_eq!(ev.get("name").unwrap().as_str(), Some("decode_step"));
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(ev.get("ts").unwrap().as_usize(), Some(100));
        assert_eq!(ev.get("dur").unwrap().as_usize(), Some(250));
        assert_eq!(ev.get("pid").unwrap().as_usize(), Some(0));
        assert_eq!(ev.get("tid").unwrap().as_usize(), Some(7));
        assert_eq!(
            ev.get("args").unwrap().get("batch").unwrap().as_usize(),
            Some(3)
        );
        let inst = crate::util::json::Json::parse(lines[1]).unwrap();
        assert_eq!(inst.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(inst.get("s").unwrap().as_str(), Some("t"));
        std::fs::remove_file(&path).ok();
    }
}
