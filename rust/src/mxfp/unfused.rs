//! The *unfused* quantization pipeline — the baseline of Tables 6 and 7.
//!
//! The paper ablates its fused Triton kernel against an eager (PyTorch
//! SDPA-style) pipeline where quantization, low-bit encoding, packing and
//! scale conversion run as separate operators, each materializing its
//! intermediate in memory and paying a dispatch/launch cost. We
//! reproduce that structure faithfully: every stage below allocates its
//! output buffer, walks the whole tensor, and is timed individually
//! under the operator names the paper's profiler reports (Table 7).
//!
//! [`FusionConfig`] toggles the four fusion components of Table 6
//! (Encode / Pack / Scale-Cvt / MP); `run_pipeline` executes the
//! resulting staged or fused computation and returns per-operator wall
//! times.

use super::block::Granularity;
use super::fused::{dual_quant, DualQuantized};
use super::{e2m1, e8m0, fp8, pack, LOG2_E, MXFP_BLOCK, NVFP4_BLOCK};
use std::time::Instant;

/// Table 6 ablation switches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FusionConfig {
    /// FP16->MX element encoding happens in-kernel (vs eager op chains).
    pub encode: bool,
    /// Two FP4 values packed into one byte in-kernel.
    pub pack: bool,
    /// Microscaling scale converted to E8M0 in-kernel.
    pub scale_cvt: bool,
    /// Both precisions produced by one single fused kernel.
    pub mp: bool,
}

impl FusionConfig {
    pub const UNFUSED: FusionConfig =
        FusionConfig { encode: false, pack: false, scale_cvt: false, mp: false };
    pub const FULLY_FUSED: FusionConfig =
        FusionConfig { encode: true, pack: true, scale_cvt: true, mp: true };

    pub fn label(&self) -> String {
        format!(
            "encode={} pack={} scale_cvt={} mp={}",
            self.encode as u8, self.pack as u8, self.scale_cvt as u8, self.mp as u8
        )
    }
}

/// One timed operator invocation (Table 7 row).
#[derive(Clone, Debug)]
pub struct OpTime {
    pub phase: &'static str,
    pub op: &'static str,
    pub nanos: u128,
}

/// Result of a pipeline run: outputs plus the operator timeline.
pub struct PipelineRun {
    pub out: DualQuantized,
    pub ops: Vec<OpTime>,
    /// Number of distinct "kernel launches" (per-operator passes) —
    /// feeds the launch-overhead term of the B200 projection.
    pub launches: usize,
}

impl PipelineRun {
    pub fn total_nanos(&self) -> u128 {
        self.ops.iter().map(|o| o.nanos).sum()
    }

    pub fn phase_nanos(&self, phase: &str) -> u128 {
        self.ops.iter().filter(|o| o.phase == phase).map(|o| o.nanos).sum()
    }
}

macro_rules! timed {
    ($ops:expr, $phase:literal, $name:literal, $body:expr) => {{
        let t0 = Instant::now();
        let r = $body;
        $ops.push(OpTime { phase: $phase, op: $name, nanos: t0.elapsed().as_nanos() });
        r
    }};
}

fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// The eager "element encoding" chain for ONE precision branch, written
/// the way a tensor library executes it: one whole-tensor pass per op.
#[allow(clippy::too_many_arguments)]
fn eager_encode_branch(
    ops: &mut Vec<OpTime>,
    launches: &mut usize,
    scaled: &[f32],
    rows: usize,
    d: usize,
    block: usize,
    fp4: bool,
) -> (Vec<f32>, Vec<u8>, Vec<f32>) {
    let nb = d / block;

    // MinOps + ArgMinOps: eager amax via min/max reductions that also
    // materialize index tensors (mirroring torch.min/argmin dispatch).
    let mut bmax = vec![0f32; rows * nb];
    timed!(ops, "encode", "MinOps", {
        for r in 0..rows {
            for b in 0..nb {
                bmax[r * nb + b] = amax(&scaled[r * d + b * block..r * d + (b + 1) * block]);
            }
        }
    });
    *launches += 1;
    let mut argidx = vec![0u32; rows * nb];
    timed!(ops, "encode", "ArgMinOps", {
        for r in 0..rows {
            for b in 0..nb {
                let blk = &scaled[r * d + b * block..r * d + (b + 1) * block];
                let mut best = 0usize;
                for (i, v) in blk.iter().enumerate() {
                    if v.abs() > blk[best].abs() {
                        best = i;
                    }
                }
                argidx[r * nb + b] = best as u32;
            }
        }
    });
    *launches += 1;

    // MulFunctor: per-block scale division materialized as a new tensor.
    let mut scales = vec![0f32; rows * nb];
    timed!(ops, "encode", "MulFunctor", {
        for (s, &m) in scales.iter_mut().zip(&bmax) {
            *s = if fp4 {
                fp8::quantize_e4m3(m / e2m1::E2M1_MAX).max((-9.0f32).exp2())
            } else {
                e8m0::shared_scale(m, fp8::E4M3_EMAX).0
            };
        }
    });
    *launches += 1;

    let mut divided = vec![0f32; rows * d];
    timed!(ops, "encode", "Direct_Copy", {
        for r in 0..rows {
            for b in 0..nb {
                let s = 1.0 / scales[r * nb + b];
                for i in 0..block {
                    divided[r * d + b * block + i] = scaled[r * d + b * block + i] * s;
                }
            }
        }
    });
    *launches += 1;

    // CompareEq + AddOps: the threshold-indicator chain of Algorithm 3
    // executed as separate whole-tensor comparisons and additions.
    let mut exps = vec![0u8; rows * d];
    timed!(ops, "encode", "CompareEq", {
        if fp4 {
            for (e, &v) in exps.iter_mut().zip(&divided) {
                let a = v.abs();
                *e = (a >= 1.0) as u8 + (a >= 2.0) as u8 + (a >= 4.0) as u8;
            }
        } else {
            for (e, &v) in exps.iter_mut().zip(&divided) {
                let a = v.abs().clamp(1e-30, fp8::E4M3_MAX);
                *e = (super::floor_log2(a).clamp(-6, 8) + 7) as u8;
            }
        }
    });
    *launches += 1;

    let mut codes = vec![0u8; rows * d];
    timed!(ops, "encode", "AddOps", {
        if fp4 {
            for (c, &v) in codes.iter_mut().zip(&divided) {
                *c = e2m1::encode(v.clamp(-e2m1::E2M1_MAX, e2m1::E2M1_MAX));
            }
        } else {
            for (c, &v) in codes.iter_mut().zip(&divided) {
                *c = fp8::encode_e4m3(v.clamp(-fp8::E4M3_MAX, fp8::E4M3_MAX));
            }
        }
    });
    *launches += 1;

    // Memcpy/Memset: staging buffer initialization the fused kernel
    // never needs.
    let staging = timed!(ops, "encode", "Memcpy", { codes.clone() });
    *launches += 1;

    (divided, staging, scales)
}

/// Run the quantization pipeline for one tensor under a fusion config.
///
/// Fully fused (`mp=true` implies the rest) delegates to
/// [`super::fused::dual_quant`]; staged configurations execute eager op
/// chains and then *still* produce the same `DualQuantized` output, so
/// all configurations are output-equivalent (asserted in tests).
pub fn run_pipeline(
    x: &[f32],
    rows: usize,
    d: usize,
    is_query: bool,
    cfg: FusionConfig,
) -> PipelineRun {
    let mut ops = Vec::new();
    let mut launches = 0usize;

    if cfg.mp {
        // Single fused kernel for both precisions (the DMA design).
        let t0 = Instant::now();
        let out = dual_quant(x, rows, d, is_query, Granularity::PerToken);
        ops.push(OpTime { phase: "fused", op: "Kernel Fusion (Ours)", nanos: t0.elapsed().as_nanos() });
        launches += 1;
        return PipelineRun { out, ops, launches };
    }

    // Shared pre-scale pass (softmax factor + S_q) — eager.
    let pre = if is_query { LOG2_E / (d as f32).sqrt() } else { 1.0 };
    let range = fp8::E4M3_MAX * e2m1::E2M1_MAX;
    let mut sq = vec![0f32; rows];
    timed!(&mut ops, "encode", "MinOps", {
        for r in 0..rows {
            sq[r] = (amax(&x[r * d..(r + 1) * d]) * pre / range).max(1e-30);
        }
    });
    launches += 1;
    let mut scaled = vec![0f32; rows * d];
    timed!(&mut ops, "encode", "MulFunctor", {
        for r in 0..rows {
            let inv = pre / sq[r];
            for i in 0..d {
                scaled[r * d + i] = x[r * d + i] * inv;
            }
        }
    });
    launches += 1;

    let (fp4_branch, fp8_branch);
    if cfg.encode {
        // In-kernel encoding: one pass per branch, no op chains.
        let t0 = Instant::now();
        let mut codes4 = vec![0u8; rows * d];
        let mut s4 = vec![0f32; rows * d / NVFP4_BLOCK];
        for r in 0..rows {
            for b in 0..d / NVFP4_BLOCK {
                let blk = &scaled[r * d + b * NVFP4_BLOCK..r * d + (b + 1) * NVFP4_BLOCK];
                let s = fp8::quantize_e4m3(amax(blk) / e2m1::E2M1_MAX).max((-9.0f32).exp2());
                s4[r * d / NVFP4_BLOCK + b] = s;
                let inv = 1.0 / s;
                for (i, &v) in blk.iter().enumerate() {
                    codes4[r * d + b * NVFP4_BLOCK + i] =
                        e2m1::encode((v * inv).clamp(-e2m1::E2M1_MAX, e2m1::E2M1_MAX));
                }
            }
        }
        ops.push(OpTime { phase: "encode", op: "FusedEncodeFP4", nanos: t0.elapsed().as_nanos() });
        launches += 1;
        let t0 = Instant::now();
        let mut codes8 = vec![0u8; rows * d];
        let mut s8 = vec![0f32; rows * d / MXFP_BLOCK];
        for r in 0..rows {
            for b in 0..d / MXFP_BLOCK {
                let blk = &scaled[r * d + b * MXFP_BLOCK..r * d + (b + 1) * MXFP_BLOCK];
                let (s, _) = e8m0::shared_scale(amax(blk), fp8::E4M3_EMAX);
                s8[r * d / MXFP_BLOCK + b] = s;
                let inv = 1.0 / s;
                for (i, &v) in blk.iter().enumerate() {
                    codes8[r * d + b * MXFP_BLOCK + i] =
                        fp8::encode_e4m3((v * inv).clamp(-fp8::E4M3_MAX, fp8::E4M3_MAX));
                }
            }
        }
        ops.push(OpTime { phase: "encode", op: "FusedEncodeFP8", nanos: t0.elapsed().as_nanos() });
        launches += 1;
        fp4_branch = (codes4, s4);
        fp8_branch = (codes8, s8);
    } else {
        let (_, c4, s4) =
            eager_encode_branch(&mut ops, &mut launches, &scaled, rows, d, NVFP4_BLOCK, true);
        let (_, c8, s8) =
            eager_encode_branch(&mut ops, &mut launches, &scaled, rows, d, MXFP_BLOCK, false);
        fp4_branch = (c4, s4);
        fp8_branch = (c8, s8);
    }
    let (codes4, s4_vals) = fp4_branch;
    let (codes8, s8_vals) = fp8_branch;

    // ---- Packing phase (Table 7: lshift + BitwiseOr as separate ops) --
    let mut packed = vec![0u8; rows * d / 2];
    if cfg.pack {
        timed!(&mut ops, "pack", "FusedPack", {
            pack::pack_row(&codes4, &mut packed);
        });
        launches += 1;
    } else {
        let mut shifted = vec![0u8; rows * d / 2];
        timed!(&mut ops, "pack", "lshift", {
            for (o, pair) in shifted.iter_mut().zip(codes4.chunks_exact(2)) {
                *o = pair[1] << 4;
            }
        });
        launches += 1;
        timed!(&mut ops, "pack", "BitwiseOr", {
            for (o, (s, pair)) in packed
                .iter_mut()
                .zip(shifted.iter().zip(codes4.chunks_exact(2)))
            {
                *o = s | (pair[0] & 0x0F);
            }
        });
        launches += 1;
    }

    // ---- Scale conversion phase (Table 7 rows) -----------------------
    let nb4 = rows * d / NVFP4_BLOCK;
    let nb8 = rows * d / MXFP_BLOCK;
    let mut s4_codes = vec![0u8; nb4];
    let mut s8_codes = vec![0u8; nb8];
    if cfg.scale_cvt {
        timed!(&mut ops, "scale", "FusedScaleCvt", {
            for (c, &s) in s4_codes.iter_mut().zip(&s4_vals) {
                *c = fp8::encode_e4m3(s);
            }
            for (c, &s) in s8_codes.iter_mut().zip(&s8_vals) {
                *c = (super::floor_log2(s.max(1e-30)) + 127).clamp(0, 254) as u8;
            }
        });
        launches += 1;
    } else {
        let mut log2s = vec![0i32; nb8];
        timed!(&mut ops, "scale", "IndexOps", {
            for (l, &s) in log2s.iter_mut().zip(&s8_vals) {
                *l = super::floor_log2(s.max(1e-30));
            }
        });
        launches += 1;
        timed!(&mut ops, "scale", "DeviceSelectSweep", {
            for (c, &l) in s8_codes.iter_mut().zip(&log2s) {
                *c = (l + 127).clamp(0, 254) as u8;
            }
        });
        launches += 1;
        timed!(&mut ops, "scale", "Write_Indices", {
            for (c, &s) in s4_codes.iter_mut().zip(&s4_vals) {
                *c = fp8::encode_e4m3(s);
            }
        });
        launches += 1;
        let _staged: Vec<u8> = timed!(&mut ops, "scale", "Direct_Copy", { s8_codes.clone() });
        launches += 1;
        let _staged2: Vec<u8> = timed!(&mut ops, "scale", "Memcpy", { s4_codes.clone() });
        launches += 1;
    }

    let out = DualQuantized {
        rows,
        d,
        packed_fp4: packed,
        s4_codes,
        fp8_codes: codes8,
        s8_codes,
        sq,
    };
    PipelineRun { out, ops, launches }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(rows: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * d).map(|_| rng.normal() as f32).collect()
    }

    fn configs() -> Vec<FusionConfig> {
        vec![
            FusionConfig::UNFUSED,
            FusionConfig { encode: true, pack: false, scale_cvt: false, mp: false },
            FusionConfig { encode: true, pack: true, scale_cvt: false, mp: false },
            FusionConfig { encode: true, pack: true, scale_cvt: true, mp: false },
            FusionConfig::FULLY_FUSED,
        ]
    }

    #[test]
    fn all_configs_output_equivalent() {
        let (rows, d) = (64, 64);
        let x = randn(rows, d, 1);
        let reference = run_pipeline(&x, rows, d, true, FusionConfig::FULLY_FUSED);
        for cfg in configs() {
            let run = run_pipeline(&x, rows, d, true, cfg);
            assert_eq!(run.out.packed_fp4, reference.out.packed_fp4, "{}", cfg.label());
            assert_eq!(run.out.fp8_codes, reference.out.fp8_codes, "{}", cfg.label());
            assert_eq!(run.out.s4_codes, reference.out.s4_codes, "{}", cfg.label());
            assert_eq!(run.out.s8_codes, reference.out.s8_codes, "{}", cfg.label());
        }
    }

    #[test]
    fn launch_count_strictly_decreases_with_fusion() {
        let (rows, d) = (32, 64);
        let x = randn(rows, d, 2);
        let launches: Vec<usize> = configs()
            .into_iter()
            .map(|c| run_pipeline(&x, rows, d, true, c).launches)
            .collect();
        for w in launches.windows(2) {
            assert!(w[1] < w[0], "launches {launches:?} not strictly decreasing");
        }
        assert_eq!(*launches.last().unwrap(), 1);
    }

    #[test]
    fn unfused_encode_dominates_breakdown() {
        // Table 7's key observation: element encoding is ~95% of the
        // unfused pipeline.
        let (rows, d) = (512, 128);
        let x = randn(rows, d, 3);
        let run = run_pipeline(&x, rows, d, true, FusionConfig::UNFUSED);
        let encode = run.phase_nanos("encode") as f64;
        let total = run.total_nanos() as f64;
        assert!(encode / total > 0.6, "encode share {}", encode / total);
    }

    #[test]
    fn op_names_match_table7() {
        let (rows, d) = (32, 64);
        let x = randn(rows, d, 4);
        let run = run_pipeline(&x, rows, d, true, FusionConfig::UNFUSED);
        let names: Vec<&str> = run.ops.iter().map(|o| o.op).collect();
        for expected in ["MinOps", "ArgMinOps", "Direct_Copy", "CompareEq",
                         "AddOps", "MulFunctor", "Memcpy", "lshift",
                         "BitwiseOr", "IndexOps", "DeviceSelectSweep",
                         "Write_Indices"] {
            assert!(names.contains(&expected), "missing op {expected}");
        }
    }
}
