//! E8M0 shared-exponent scales (paper Algorithm 2, Steps 6–7).
//!
//! The shared scale of MXFP4/MXFP8 blocks is a pure power of two stored
//! as a biased u8: `code = S_shared + 127`, clamped to [0, 254] (255 is
//! reserved for NaN). `S_shared = floor(log2(amax)) - e_max` aligns the
//! block's largest exponent with the element format's largest normal
//! exponent, maximizing usable dynamic range.

use super::floor_log2;

/// Exact 2^e for e in [-126, 127] via direct bit construction (hot
/// decode path, no libm). Matches the Python side's `pow2i`: e < -126
/// (the subnormal E8M0 corner, reachable only for degenerate blocks)
/// clamps to 2^-126.
#[inline]
fn pow2i(e: i32) -> f32 {
    f32::from_bits(((e.clamp(-126, 127) + 127) as u32) << 23)
}

/// Compute the E8M0 scale for a block: returns `(scale, code)` with
/// `scale == 2^(code as i32 - 127)` exactly (for codes >= 1).
#[inline]
pub fn shared_scale(block_amax: f32, emax: i32) -> (f32, u8) {
    let amax = block_amax.max(1e-30);
    let s_shared = floor_log2(amax) - emax;
    let code = (s_shared + 127).clamp(0, 254) as u8;
    (pow2i(code as i32 - 127), code)
}

/// Decode an E8M0 code back into its power-of-two scale.
#[inline]
pub fn decode(code: u8) -> f32 {
    pow2i(code as i32 - 127)
}

/// The full 256-entry decode table, built once from [`decode`] so it is
/// bit-exact with the arithmetic decoder by construction. The hot row
/// decoders hoist this reference once per tile.
#[inline]
pub fn table() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| std::array::from_fn(|c| decode(c as u8)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfp::{e2m1::E2M1_EMAX, fp8::E4M3_EMAX};

    #[test]
    fn lut_matches_arithmetic_decoder_exhaustive() {
        // All 256 codes: the table equals the arithmetic decoder bit for
        // bit, and both equal an independent exp2 reconstruction over the
        // representable exponent range (the e < -126 corner clamps).
        for code in 0u16..=255 {
            let code = code as u8;
            let e = (code as i32 - 127).clamp(-126, 127);
            let arith = (e as f32).exp2();
            assert_eq!(decode(code).to_bits(), arith.to_bits(), "code {code}");
            assert_eq!(table()[code as usize].to_bits(), arith.to_bits());
        }
    }

    #[test]
    fn amax_448_e4m3_gives_unit_scale() {
        // floor(log2(448)) = 8, minus emax 8 -> 2^0, code 127.
        let (s, c) = shared_scale(448.0, E4M3_EMAX);
        assert_eq!(s, 1.0);
        assert_eq!(c, 127);
    }

    #[test]
    fn scale_matches_code_always() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..10_000 {
            let amax = rng.uniform_in(-30.0, 30.0).exp2();
            for emax in [E2M1_EMAX, E4M3_EMAX] {
                let (s, c) = shared_scale(amax, emax);
                assert_eq!(s, decode(c));
            }
        }
    }

    #[test]
    fn block_max_fits_after_scaling() {
        // After dividing by the scale, amax lands in (emax-1, emax] octave
        // so the element format can represent it (up to mantissa rounding).
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..5000 {
            let amax = rng.uniform_in(-20.0, 20.0).exp2();
            let (s, _) = shared_scale(amax, E2M1_EMAX);
            let scaled = amax / s;
            assert!(scaled < 2.0 * (E2M1_EMAX as f32).exp2() + 1e-3,
                    "amax={amax} scaled={scaled}");
        }
    }

    #[test]
    fn extreme_values_clamped() {
        // Degenerate amax is floored at 1e-30 (like the Python side), so
        // the code lands far below the midpoint but stays in range.
        let (_, c_lo) = shared_scale(1e-38, E4M3_EMAX);
        let (_, c_hi) = shared_scale(3e38, E4M3_EMAX);
        assert!(c_lo < 64, "c_lo {c_lo}");
        assert!(c_hi <= 254);
        assert_eq!(shared_scale(0.0, E4M3_EMAX).1, c_lo);
    }

    #[test]
    fn code_never_255() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..10_000 {
            let amax = rng.uniform_in(0.0, 3.0e38);
            let (_, c) = shared_scale(amax, E4M3_EMAX);
            assert_ne!(c, 255);
        }
    }
}
