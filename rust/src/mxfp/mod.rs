//! The MXFP format zoo (paper Table 1) in Rust, bit-compatible with the
//! Pallas/jnp implementation in `python/compile/kernels/mxfp.py`.
//!
//! | Name  | Block | Element    | Shared scale |
//! |-------|-------|------------|--------------|
//! | MXFP8 | 32    | E4M3/E5M2  | E8M0 (8 bit) |
//! | MXFP4 | 32    | E2M1       | E8M0 (8 bit) |
//! | NVFP4 | 16    | E2M1       | E4M3 (8 bit) |
//!
//! Submodules:
//! * [`e2m1`]   — FP4 encode/decode (paper Algorithm 3)
//! * [`fp8`]    — E4M3 / E5M2 codecs
//! * [`e8m0`]   — shared-exponent scales (Alg. 2 Steps 6–7)
//! * [`pack`]   — two-FP4-per-byte nibble packing (Alg. 2 Step 5)
//! * [`block`]  — block fake-quantization of the three formats at
//!                per-tensor / per-block / per-token granularity (Tab. 8)
//! * [`fused`]  — single-pass dual-format pipeline (Alg. 2 end to end)
//! * [`unfused`]— the multi-kernel-launch baseline with per-operator
//!                timing (Tables 6 and 7)

pub mod block;
pub mod e2m1;
pub mod e8m0;
pub mod fp8;
pub mod fused;
pub mod pack;
pub mod unfused;

/// NVFP4 groups 16 elements per shared scale.
pub const NVFP4_BLOCK: usize = 16;
/// MXFP4 / MXFP8 group 32 elements per shared scale.
pub const MXFP_BLOCK: usize = 32;
/// log2(e): folded into Q so the kernel softmax runs in base-2.
pub const LOG2_E: f32 = std::f32::consts::LOG2_E;

/// Exact floor(log2(a)) for finite positive f32 (bit-level; no libm
/// rounding hazards — mirrors `_floor_log2` on the Python side).
#[inline]
pub fn floor_log2(a: f32) -> i32 {
    debug_assert!(a > 0.0 && a.is_finite());
    let bits = a.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    if exp != 0 {
        exp - 127
    } else {
        // Subnormal: log2(mantissa * 2^-149).
        let mant = bits & 0x7F_FFFF;
        -149 + (31 - mant.leading_zeros() as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_log2_powers() {
        for e in -120..120 {
            let v = (e as f32).exp2();
            assert_eq!(floor_log2(v), e, "2^{e}");
            assert_eq!(floor_log2(v * 1.5), e);
            if e > -120 {
                assert_eq!(floor_log2(v * 0.99), e - 1);
            }
        }
    }

    #[test]
    fn floor_log2_subnormals() {
        let tiny = f32::from_bits(1); // 2^-149
        assert_eq!(floor_log2(tiny), -149);
        assert_eq!(floor_log2(f32::from_bits(0b10)), -148);
    }

    #[test]
    fn floor_log2_matches_naive() {
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..10_000 {
            let v = (rng.uniform_in(-30.0, 30.0)).exp2() as f32;
            let naive = {
                let mut e = v.log2().floor() as i32;
                if v >= ((e + 1) as f32).exp2() {
                    e += 1;
                }
                if v < (e as f32).exp2() {
                    e -= 1;
                }
                e
            };
            assert_eq!(floor_log2(v), naive, "v={v}");
        }
    }
}
