//! E4M3 / E5M2 (FP8) codecs.
//!
//! * **E4M3** follows the OCP "FN" variant used on Blackwell: bias 7,
//!   max normal 448 (S.1111.110); S.1111.111 is NaN and never emitted.
//!   Normals cover exponents [-6, 8], subnormal step 2^-9.
//! * **E5M2** is IEEE-like: bias 15, max normal 57344, exponents
//!   [-14, 15], subnormal step 2^-16 (inf/NaN exponent never emitted —
//!   values are clamped first).
//!
//! Value-level quantization is round-to-nearest-even on the format grid,
//! identical to `mxfp.py::quantize_e4m3/quantize_e5m2` (f32 `round_ties_even`).

use super::floor_log2;

pub const E4M3_MAX: f32 = 448.0;
pub const E4M3_EMAX: i32 = 8;
pub const E5M2_MAX: f32 = 57344.0;
pub const E5M2_EMAX: i32 = 15;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fp8Kind {
    E4M3,
    E5M2,
}

struct Spec {
    emin: i32,
    emax: i32,
    mant_bits: i32,
    max: f32,
    bias: i32,
    exp_shift: u32,
    mant_mask: u8,
}

const fn spec(kind: Fp8Kind) -> Spec {
    match kind {
        Fp8Kind::E4M3 => Spec {
            emin: -6,
            emax: E4M3_EMAX,
            mant_bits: 3,
            max: E4M3_MAX,
            bias: 7,
            exp_shift: 3,
            mant_mask: 0x07,
        },
        Fp8Kind::E5M2 => Spec {
            emin: -14,
            emax: E5M2_EMAX,
            mant_bits: 2,
            max: E5M2_MAX,
            bias: 15,
            exp_shift: 2,
            mant_mask: 0x03,
        },
    }
}

/// RTN-even onto the FP8 grid, value level (clamped to the max normal).
/// Hot path: one encode (bit-twiddled) + one table lookup.
#[inline]
pub fn quantize(x: f32, kind: Fp8Kind) -> f32 {
    decode(encode(x, kind), kind)
}

/// Reference (slow) quantizer kept for differential testing.
#[cfg(test)]
fn quantize_reference(x: f32, kind: Fp8Kind) -> f32 {
    let s = spec(kind);
    let a = x.abs().min(s.max);
    if a == 0.0 {
        return 0.0;
    }
    let e = floor_log2(a).clamp(s.emin, s.emax);
    let step = ((e - s.mant_bits) as f32).exp2();
    let q = ((a / step).round_ties_even() * step).min(s.max);
    if x < 0.0 {
        -q
    } else {
        q
    }
}

pub fn quantize_e4m3(x: f32) -> f32 {
    quantize(x, Fp8Kind::E4M3)
}

pub fn quantize_e5m2(x: f32) -> f32 {
    quantize(x, Fp8Kind::E5M2)
}

/// Encode to the 8-bit code with round-to-nearest-even, by integer
/// rounding directly on the f32 bit pattern (no libm in the hot path).
/// Any finite f32 is accepted (clamped); NaN patterns are never produced.
#[inline]
pub fn encode(x: f32, kind: Fp8Kind) -> u8 {
    let s = spec(kind);
    let sign = ((x.to_bits() >> 24) & 0x80) as u8;
    let a = x.abs().min(s.max);
    let min_normal_bits = (((s.emin + 127) as u32) << 23);
    let ab = a.to_bits();
    if ab >= min_normal_bits {
        // Normal: RTN-even the f32 mantissa down to `mant_bits` by adding
        // the classic (half - 1 + lsb) bias at the cut position; a
        // mantissa carry correctly bumps the exponent.
        let cut = 23 - s.mant_bits as u32;
        let lsb = (ab >> cut) & 1;
        let rounded = ab + ((1u32 << (cut - 1)) - 1) + lsb;
        let e = ((rounded >> 23) as i32) - 127;
        if e > s.emax {
            // Unreachable after the clamp (kept as a safety net): return
            // the max-normal code. E4M3-FN reserves mant=111 at emax for
            // NaN, so its max-normal mantissa is mant_mask - 1.
            let max_mant = s.mant_mask - matches!(kind, Fp8Kind::E4M3) as u8;
            return sign | (((s.emax + s.bias) as u8) << s.exp_shift) | max_mant;
        }
        let m = ((rounded >> cut) as u8) & s.mant_mask;
        sign | (((e + s.bias) as u8) << s.exp_shift) | m
    } else {
        // Subnormal: magnitude in units of 2^(emin - mant_bits).
        let scale = f32::from_bits(((s.mant_bits - s.emin + 127) as u32) << 23);
        let m = (a * scale).round_ties_even() as u8;
        if m > s.mant_mask {
            sign | (1 << s.exp_shift) // rounded up into the min normal
        } else {
            sign | m
        }
    }
}

/// Decode an 8-bit code to f32 via precomputed tables.
#[inline]
pub fn decode(code: u8, kind: Fp8Kind) -> f32 {
    match kind {
        Fp8Kind::E4M3 => e4m3_lut()[code as usize],
        Fp8Kind::E5M2 => e5m2_lut()[code as usize],
    }
}

/// The full 256-entry E4M3 decode table. Hot row decoders hoist this
/// reference once per tile so the inner loop is a bare indexed load —
/// no per-element kind dispatch or `OnceLock` read. Built from
/// [`decode_arith`] once, so it is bit-exact with the arithmetic
/// decoder by construction.
#[inline]
pub fn e4m3_table() -> &'static [f32; 256] {
    e4m3_lut()
}

/// The full 256-entry E5M2 decode table (see [`e4m3_table`]).
#[inline]
pub fn e5m2_table() -> &'static [f32; 256] {
    e5m2_lut()
}

/// Reference arithmetic decoder the tables are built from (and checked
/// against exhaustively in tests). Not for hot paths.
pub fn decode_arith(code: u8, kind: Fp8Kind) -> f32 {
    let s = spec(kind);
    let sign = if code >> 7 == 1 { -1.0f32 } else { 1.0 };
    let exp_field = ((code >> s.exp_shift) & ((1 << (7 - s.exp_shift)) - 1)) as i32;
    let m = (code & s.mant_mask) as f32;
    let pow2 = |e: i32| f32::from_bits(((e + 127) as u32) << 23);
    let mag = if exp_field == 0 {
        m * pow2(s.emin - s.mant_bits)
    } else {
        (1.0 + m * pow2(-s.mant_bits)) * pow2(exp_field - s.bias)
    };
    sign * mag
}

fn e4m3_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        std::array::from_fn(|c| decode_arith(c as u8, Fp8Kind::E4M3))
    })
}

fn e5m2_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        std::array::from_fn(|c| decode_arith(c as u8, Fp8Kind::E5M2))
    })
}

pub fn encode_e4m3(x: f32) -> u8 {
    encode(x, Fp8Kind::E4M3)
}

pub fn decode_e4m3(code: u8) -> f32 {
    decode(code, Fp8Kind::E4M3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_arithmetic_decoder_exhaustive() {
        // Every one of the 256 codes, both formats: the table the hot
        // decoders index must equal the arithmetic decoder bit for bit
        // (including -0.0 and subnormal codes).
        for code in 0u16..=255 {
            let code = code as u8;
            for kind in [Fp8Kind::E4M3, Fp8Kind::E5M2] {
                assert_eq!(
                    decode(code, kind).to_bits(),
                    decode_arith(code, kind).to_bits(),
                    "{kind:?} code {code:#04x}"
                );
            }
            assert_eq!(
                e4m3_table()[code as usize].to_bits(),
                decode_arith(code, Fp8Kind::E4M3).to_bits()
            );
            assert_eq!(
                e5m2_table()[code as usize].to_bits(),
                decode_arith(code, Fp8Kind::E5M2).to_bits()
            );
        }
    }

    #[test]
    fn e4m3_clamps_to_448() {
        assert_eq!(quantize_e4m3(1000.0), 448.0);
        assert_eq!(quantize_e4m3(-1000.0), -448.0);
        assert_eq!(quantize_e4m3(448.0), 448.0);
    }

    #[test]
    fn e4m3_code_round_trip_exhaustive() {
        for code in 0u16..=255 {
            let code = code as u8;
            if code & 0x7F == 0x7F {
                continue; // NaN pattern
            }
            let v = decode(code, Fp8Kind::E4M3);
            let rt = encode(v, Fp8Kind::E4M3);
            assert_eq!(decode(rt, Fp8Kind::E4M3), v, "code {code:#04x}");
        }
    }

    #[test]
    fn e5m2_code_round_trip_exhaustive() {
        for code in 0u16..=255 {
            let code = code as u8;
            if (code >> 2) & 0x1F == 0x1F {
                continue; // inf/NaN exponent
            }
            let v = decode(code, Fp8Kind::E5M2);
            let rt = encode(v, Fp8Kind::E5M2);
            assert_eq!(decode(rt, Fp8Kind::E5M2), v, "code {code:#04x}");
        }
    }

    #[test]
    fn e4m3_subnormals() {
        let step = (-9.0f32).exp2();
        assert_eq!(quantize_e4m3(step), step);
        assert_eq!(quantize_e4m3(3.0 * step), 3.0 * step);
        assert_eq!(quantize_e4m3(0.4 * step), 0.0);
        assert_eq!(quantize_e4m3(0.6 * step), step);
    }

    #[test]
    fn e4m3_relative_error_bound() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..20_000 {
            let v = rng.uniform_in(-448.0, 448.0);
            let q = quantize_e4m3(v);
            if v.abs() >= (-6.0f32).exp2() {
                assert!(
                    (q - v).abs() <= v.abs() * (-4.0f32).exp2() + 1e-12,
                    "v={v} q={q}"
                );
            } else {
                assert!((q - v).abs() <= (-10.0f32).exp2() + 1e-12);
            }
        }
    }

    #[test]
    fn quantize_monotone() {
        let mut prev = f32::NEG_INFINITY;
        let mut v = -500.0f32;
        while v < 500.0 {
            let q = quantize_e4m3(v);
            assert!(q >= prev, "v={v}");
            prev = q;
            v += 0.37;
        }
    }

    #[test]
    fn ties_round_to_even() {
        // Between 448 and 480 the grid step at e=8 is 32; 464 is the
        // midpoint of {448, 480} but 480 exceeds max -> clamps to 448.
        assert_eq!(quantize_e4m3(464.0), 448.0);
        // At e=3 the step is 1: 8.5 between 8 and 9 -> mantissa even => 8.
        assert_eq!(quantize_e4m3(8.5), 8.0);
        assert_eq!(quantize_e4m3(9.5), 10.0); // 9.5 -> 10 (even mantissa 2)
    }

    #[test]
    fn e5m2_coarser_than_e4m3_in_normal_range() {
        let mut rng = crate::util::rng::Rng::new(4);
        let mut err3 = 0.0f64;
        let mut err2 = 0.0f64;
        for _ in 0..10_000 {
            let v = rng.uniform_in(-400.0, 400.0);
            err3 += ((quantize_e4m3(v) - v).abs() as f64).powi(2);
            err2 += ((quantize_e5m2(v) - v).abs() as f64).powi(2);
        }
        assert!(err2 > 2.0 * err3, "e5m2 {err2} vs e4m3 {err3}");
    }

    #[test]
    fn idempotent() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..5000 {
            let v = rng.uniform_in(-448.0, 448.0);
            for kind in [Fp8Kind::E4M3, Fp8Kind::E5M2] {
                let q = quantize(v, kind);
                assert_eq!(quantize(q, kind), q, "{kind:?} v={v}");
            }
        }
    }
}
