//! E2M1 (FP4) encode/decode — paper Algorithm 3.
//!
//! Representable magnitudes: 0, 0.5, 1, 1.5, 2, 3, 4, 6. Encoding is the
//! paper's branch-structured thresholding: 2-bit exponent from |x| vs
//! {1, 2, 4}, 1-bit mantissa vs the normalized midpoint with a strict
//! `>` so ties round to the even mantissa (the paper's "5 rounds to 4"
//! example). Like the published algorithm, values never round up across
//! an exponent boundary (1.75 -> 1.5). Semantics are bit-identical to
//! `python/compile/kernels/mxfp.py::encode_e2m1` (cross-checked by the
//! golden-vector test in `rust/tests/integration.rs`).

/// Largest representable E2M1 magnitude.
pub const E2M1_MAX: f32 = 6.0;
/// Exponent of the largest normal (6 = 1.5 * 2^2).
pub const E2M1_EMAX: i32 = 2;

/// All representable magnitudes, ascending (index = (E << 1) | M).
pub const E2M1_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Encode one clamped value (|x| <= 6) into a 4-bit code (low nibble).
///
/// Branch-ladder form of Algorithm 3 — the decision boundaries below are
/// exactly the paper's exponent thresholds {1, 2, 4} combined with the
/// strict-`>` normalized-midpoint mantissa rule (hot path: no libm).
#[inline]
pub fn encode(x: f32) -> u8 {
    let s = ((x < 0.0) as u8) << 3;
    let a = x.abs();
    // Magnitude code ladder (see E2M1_GRID): boundaries at
    // 0.25 | 1.0 | 1.25 | 2.0 | 2.5 | 4.0 | 5.0, ties toward even M.
    let mag = if a < 2.0 {
        if a < 1.0 {
            (a > 0.25) as u8 // 0 or 1 (0.5)
        } else if a <= 1.25 {
            2 // 1.0
        } else {
            3 // 1.5
        }
    } else if a < 4.0 {
        if a <= 2.5 {
            4 // 2.0
        } else {
            5 // 3.0
        }
    } else if a <= 5.0 {
        6 // 4.0
    } else {
        7 // 6.0
    };
    s | mag
}

/// Signed decode table indexed by the full 4-bit code. Public so the hot
/// row decoders ([`crate::mxfp::fused::DualQuantized::decode_low_rows`])
/// can index it straight from packed nibbles without a function call per
/// element.
pub const DECODE_LUT: [f32; 16] = [
    0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
    -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0,
];

/// Decode a 4-bit code (low nibble) to f32.
#[inline]
pub fn decode(code: u8) -> f32 {
    DECODE_LUT[(code & 0x0F) as usize]
}

/// Clamp to [-6, 6], then encode/decode (value-level fake quant).
#[inline]
pub fn quantize(x: f32) -> f32 {
    decode(encode(x.clamp(-E2M1_MAX, E2M1_MAX)))
}

/// Encode a slice (pre-clamped by the caller or clamped here).
pub fn encode_slice(xs: &[f32], out: &mut [u8]) {
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = encode(x.clamp(-E2M1_MAX, E2M1_MAX));
    }
}

/// Decode a slice of codes.
pub fn decode_slice(codes: &[u8], out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = decode(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_arithmetic_decoder_exhaustive() {
        // All 16 codes: the table equals the arithmetic reconstruction
        // sign * E2M1_GRID[magnitude] bit for bit (-0.0 included).
        for code in 0u8..16 {
            let sign = if code & 0x8 != 0 { -1.0f32 } else { 1.0 };
            let arith = sign * E2M1_GRID[(code & 0x7) as usize];
            assert_eq!(decode(code).to_bits(), arith.to_bits(), "code {code:#x}");
            assert_eq!(DECODE_LUT[code as usize].to_bits(), arith.to_bits());
        }
    }

    #[test]
    fn representables_round_trip() {
        for (i, &v) in E2M1_GRID.iter().enumerate() {
            assert_eq!(decode(encode(v)), v, "grid[{i}]");
        }
    }

    #[test]
    fn negatives_round_trip() {
        for &v in &E2M1_GRID[1..] {
            assert_eq!(decode(encode(-v)), -v);
        }
    }

    #[test]
    fn paper_tie_example() {
        // "for input value 5, we prefer rounding to 4" (ties to even M=0).
        assert_eq!(quantize(5.0), 4.0);
        assert_eq!(quantize(-5.0), -4.0);
    }

    #[test]
    fn midpoints_strict() {
        assert_eq!(quantize(2.5), 2.0);
        assert_eq!(quantize(2.5000002), 3.0);
        assert_eq!(quantize(1.25), 1.0);
        assert_eq!(quantize(0.25), 0.0);
        assert_eq!(quantize(0.2500001), 0.5);
    }

    #[test]
    fn clamping() {
        assert_eq!(quantize(100.0), 6.0);
        assert_eq!(quantize(-100.0), -6.0);
    }

    #[test]
    fn nearest_neighbour_property() {
        // Quantized value must be one of the two grid neighbours.
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..20_000 {
            let v = rng.uniform_in(-6.0, 6.0);
            let q = quantize(v);
            let lo = E2M1_GRID
                .iter()
                .flat_map(|&g| [g, -g])
                .filter(|&g| g <= v)
                .fold(f32::NEG_INFINITY, f32::max);
            let hi = E2M1_GRID
                .iter()
                .flat_map(|&g| [g, -g])
                .filter(|&g| g >= v)
                .fold(f32::INFINITY, f32::min);
            assert!(q == lo || q == hi, "v={v} q={q} lo={lo} hi={hi}");
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..5000 {
            let v = rng.uniform_in(-6.0, 6.0);
            let q = quantize(v);
            assert_eq!(quantize(q), q);
        }
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = vec![0.0, 0.5, -1.5, 3.0, 7.0, -9.0];
        let mut codes = vec![0u8; xs.len()];
        encode_slice(&xs, &mut codes);
        let mut back = vec![0f32; xs.len()];
        decode_slice(&codes, &mut back);
        assert_eq!(back, vec![0.0, 0.5, -1.5, 3.0, 6.0, -6.0]);
    }
}
