//! FP4 nibble packing (paper Algorithm 2, Step 5).
//!
//! Two E2M1 codes per byte; the element with the higher index occupies
//! the most-significant nibble: `packed = (hi << 4) | lo`.

/// Pack pairs of 4-bit codes along a row; `codes.len()` must be even.
pub fn pack_row(codes: &[u8], out: &mut [u8]) {
    debug_assert_eq!(codes.len() % 2, 0);
    debug_assert_eq!(out.len(), codes.len() / 2);
    for (o, pair) in out.iter_mut().zip(codes.chunks_exact(2)) {
        *o = (pair[1] << 4) | (pair[0] & 0x0F);
    }
}

/// Unpack a packed row back into 4-bit codes.
pub fn unpack_row(packed: &[u8], out: &mut [u8]) {
    debug_assert_eq!(out.len(), packed.len() * 2);
    for (i, &b) in packed.iter().enumerate() {
        out[2 * i] = b & 0x0F;
        out[2 * i + 1] = (b >> 4) & 0x0F;
    }
}

/// Pack a whole buffer (row-major, contiguous).
pub fn pack(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len() / 2];
    pack_row(codes, &mut out);
    out
}

/// Unpack a whole buffer.
pub fn unpack(packed: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; packed.len() * 2];
    unpack_row(packed, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let codes: Vec<u8> = (0..64).map(|i| (i % 16) as u8).collect();
        assert_eq!(unpack(&pack(&codes)), codes);
    }

    #[test]
    fn high_index_in_high_nibble() {
        let packed = pack(&[0x3, 0xA]);
        assert_eq!(packed, vec![(0xA << 4) | 0x3]);
    }

    #[test]
    fn halves_the_size() {
        let codes = vec![1u8; 128];
        assert_eq!(pack(&codes).len(), 64);
    }

    #[test]
    fn property_random_round_trip() {
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..200 {
            let n = 2 * (1 + rng.below(64) as usize);
            let codes: Vec<u8> = (0..n).map(|_| (rng.below(16)) as u8).collect();
            assert_eq!(unpack(&pack(&codes)), codes);
        }
    }
}
