//! Block fake-quantization of the three MXFP formats (value level), plus
//! the quantization-granularity variants of Table 8.
//!
//! "Fake quant" = quantize then dequantize; this is what the error
//! studies (Tables 2/5/8, Fig. 1) operate on. The bit-level pipeline
//! (codes + packed nibbles) lives in [`super::fused`].

use super::{e2m1, e8m0, fp8, MXFP_BLOCK, NVFP4_BLOCK};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Mxfp4,
    Mxfp8E4m3,
    Mxfp8E5m2,
    Nvfp4,
}

impl Format {
    pub fn name(&self) -> &'static str {
        match self {
            Format::Mxfp4 => "MXFP4",
            Format::Mxfp8E4m3 => "MXFP8",
            Format::Mxfp8E5m2 => "MXFP8-E5M2",
            Format::Nvfp4 => "NVFP4",
        }
    }

    /// Bits per element (elements only; scales add 8 bits per block).
    pub fn element_bits(&self) -> usize {
        match self {
            Format::Mxfp4 | Format::Nvfp4 => 4,
            _ => 8,
        }
    }

    pub fn block_size(&self) -> usize {
        match self {
            Format::Nvfp4 => NVFP4_BLOCK,
            _ => MXFP_BLOCK,
        }
    }
}

/// The per-token scale granularity of Algorithm 2 Step 2 (Table 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One S_q for the whole tensor.
    PerTensor,
    /// One S_q per tile of rows (the paper's "Per-Block"; row-tile 64).
    PerBlock,
    /// One S_q per row — the DMA default.
    PerToken,
}

fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Quantize one block (already scaled into element range) and write the
/// dequantized values. Decode tables are hoisted out of the element
/// loops (E2M1's is a const; the FP8 tables are fetched once per block).
fn quant_block_values(block: &mut [f32], format: Format) {
    match format {
        Format::Mxfp4 | Format::Nvfp4 => {
            for v in block.iter_mut() {
                *v = e2m1::quantize(*v);
            }
        }
        Format::Mxfp8E4m3 => {
            let lut = fp8::e4m3_table();
            for v in block.iter_mut() {
                let c = fp8::encode(
                    v.clamp(-fp8::E4M3_MAX, fp8::E4M3_MAX), fp8::Fp8Kind::E4M3);
                *v = lut[c as usize];
            }
        }
        Format::Mxfp8E5m2 => {
            let lut = fp8::e5m2_table();
            for v in block.iter_mut() {
                let c = fp8::encode(
                    v.clamp(-fp8::E5M2_MAX, fp8::E5M2_MAX), fp8::Fp8Kind::E5M2);
                *v = lut[c as usize];
            }
        }
    }
}

/// Block scale for one block of the given format.
fn block_scale(block_amax: f32, format: Format) -> f32 {
    match format {
        Format::Mxfp4 => e8m0::shared_scale(block_amax, e2m1::E2M1_EMAX).0,
        Format::Mxfp8E4m3 => e8m0::shared_scale(block_amax, fp8::E4M3_EMAX).0,
        Format::Mxfp8E5m2 => e8m0::shared_scale(block_amax, fp8::E5M2_EMAX).0,
        Format::Nvfp4 => {
            // E4M3-stored scale, floored at the smallest subnormal so
            // dequantization never divides by zero.
            fp8::quantize_e4m3(block_amax / e2m1::E2M1_MAX).max((-9.0f32).exp2())
        }
    }
}

/// Fake-quantize a [rows, d] row-major tensor in the given format
/// (no outer S_q scale — the Table 2 "plain format" rows).
pub fn fake_quant(x: &[f32], rows: usize, d: usize, format: Format) -> Vec<f32> {
    let bs = format.block_size();
    assert_eq!(d % bs, 0, "d={d} not a multiple of block {bs}");
    let mut out = x.to_vec();
    for r in 0..rows {
        for b in 0..d / bs {
            let blk = &mut out[r * d + b * bs..r * d + (b + 1) * bs];
            let s = block_scale(amax(blk), format);
            for v in blk.iter_mut() {
                *v /= s;
            }
            quant_block_values(blk, format);
            for v in blk.iter_mut() {
                *v *= s;
            }
        }
    }
    out
}

/// Fake-quantize with an outer quantization scale S_q at the requested
/// granularity (Alg. 2 Step 2; the "+ tokenwise" row of Table 2 and the
/// Table 8 sweep). Only meaningful for NVFP4, whose two-level range is
/// 448 * 6.
pub fn fake_quant_scaled(
    x: &[f32],
    rows: usize,
    d: usize,
    format: Format,
    granularity: Granularity,
) -> Vec<f32> {
    let range = fp8::E4M3_MAX * e2m1::E2M1_MAX;
    let row_tile = 64usize;
    let sq_for_row = |x: &[f32], r: usize| -> f32 {
        let a = match granularity {
            Granularity::PerTensor => amax(x),
            Granularity::PerBlock => {
                let start = (r / row_tile) * row_tile;
                let end = (start + row_tile).min(rows);
                amax(&x[start * d..end * d])
            }
            Granularity::PerToken => amax(&x[r * d..(r + 1) * d]),
        };
        (a / range).max(1e-30)
    };
    let mut out = vec![0.0f32; rows * d];
    let bs = format.block_size();
    for r in 0..rows {
        let sq = sq_for_row(x, r);
        let row = &x[r * d..(r + 1) * d];
        let orow = &mut out[r * d..(r + 1) * d];
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = v / sq;
        }
        for b in 0..d / bs {
            let blk = &mut orow[b * bs..(b + 1) * bs];
            let s = block_scale(amax(blk), format);
            for v in blk.iter_mut() {
                *v /= s;
            }
            quant_block_values(blk, format);
            for v in blk.iter_mut() {
                *v *= s;
            }
        }
        for o in orow.iter_mut() {
            *o *= sq;
        }
    }
    out
}

/// Single-level FP4 quantization at a given *scale granularity* — the
/// Table 8 ablation. Unlike the two-level NVFP4 scheme (whose per-16
/// E4M3 block scales absorb row heterogeneity on their own), this is the
/// classic design question: one float scale per tensor, per row-block,
/// or per token, with E2M1 elements underneath.
pub fn fake_quant_fp4_granular(
    x: &[f32],
    rows: usize,
    d: usize,
    granularity: Granularity,
) -> Vec<f32> {
    let row_tile = 64usize;
    let scale_of = |slice: &[f32]| (amax(slice) / e2m1::E2M1_MAX).max(1e-30);
    let mut out = vec![0f32; rows * d];
    let tensor_scale = scale_of(x);
    for r in 0..rows {
        let s = match granularity {
            Granularity::PerTensor => tensor_scale,
            Granularity::PerBlock => {
                let start = (r / row_tile) * row_tile;
                let end = (start + row_tile).min(rows);
                scale_of(&x[start * d..end * d])
            }
            Granularity::PerToken => scale_of(&x[r * d..(r + 1) * d]),
        };
        let inv = 1.0 / s;
        for c in 0..d {
            out[r * d + c] = e2m1::quantize(x[r * d + c] * inv) * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::util::rng::Rng;

    fn randn(rows: usize, d: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * d).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn shapes_preserved() {
        let x = randn(8, 64, 1, 1.0);
        for f in [Format::Mxfp4, Format::Mxfp8E4m3, Format::Nvfp4] {
            assert_eq!(fake_quant(&x, 8, 64, f).len(), x.len());
        }
    }

    #[test]
    fn error_ordering_matches_table2() {
        // MXFP4 error >> NVFP4 >= MXFP8 (paper Table 2). The gap shows on
        // channel-structured activations (paper Sec. 4 / Fig. 1).
        let mut rng = crate::util::rng::Rng::new(7);
        let x = crate::util::rng::channelwise_qk(&mut rng, 64, 128, 8, 8.0);
        let rel = |q: &[f32]| {
            let num: f64 = x.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let den: f64 = x.iter().map(|a| (*a as f64).powi(2)).sum();
            (num / den).sqrt()
        };
        let e4 = rel(&fake_quant(&x, 64, 128, Format::Mxfp4));
        let nv = rel(&fake_quant(&x, 64, 128, Format::Nvfp4));
        let e8 = rel(&fake_quant(&x, 64, 128, Format::Mxfp8E4m3));
        assert!(e4 > 1.15 * nv, "{e4} vs {nv}");
        assert!(nv > 2.0 * e8, "{nv} vs {e8}");
    }

    #[test]
    fn mxfp8_high_fidelity() {
        let x = randn(32, 64, 3, 1.0);
        let q = fake_quant(&x, 32, 64, Format::Mxfp8E4m3);
        assert!(metrics::cos_sim(&x, &q) > 0.998);
    }

    #[test]
    fn idempotent_all_formats() {
        let x = randn(16, 64, 9, 3.0);
        for f in [Format::Mxfp4, Format::Mxfp8E4m3, Format::Mxfp8E5m2, Format::Nvfp4] {
            let q1 = fake_quant(&x, 16, 64, f);
            let q2 = fake_quant(&q1, 16, 64, f);
            assert_eq!(q1, q2, "{f:?}");
        }
    }

    #[test]
    fn granularity_fidelity_ordering() {
        // Finer granularity must not be worse (Table 8): per-token >=
        // per-block >= per-tensor in cosine similarity, given rows with
        // heterogeneous scales.
        let mut x = randn(128, 64, 11, 1.0);
        // Heterogeneous row magnitudes.
        for r in 0..128 {
            let s = 1.0 + (r % 13) as f32;
            for v in &mut x[r * 64..(r + 1) * 64] {
                *v *= s;
            }
        }
        let sim = |g| {
            let q = fake_quant_scaled(&x, 128, 64, Format::Nvfp4, g);
            metrics::cos_sim(&x, &q)
        };
        let t = sim(Granularity::PerToken);
        let b = sim(Granularity::PerBlock);
        let n = sim(Granularity::PerTensor);
        // Adjacent granularities can tie within noise; the end-to-end
        // ordering must hold strictly.
        assert!(t >= b - 2e-3, "token {t} < block {b}");
        assert!(b >= n - 2e-3, "block {b} < tensor {n}");
        assert!(t >= n - 2e-3, "token {t} < tensor {n}");
    }

    #[test]
    fn outlier_rows_contained_with_per_token() {
        let mut x = randn(64, 64, 13, 1.0);
        for v in &mut x[11 * 64..12 * 64] {
            *v *= 1000.0;
        }
        let q = fake_quant_scaled(&x, 64, 64, Format::Nvfp4, Granularity::PerToken);
        // Other rows unaffected by the outlier row.
        let row3 = &x[3 * 64..4 * 64];
        let q3 = &q[3 * 64..4 * 64];
        assert!(metrics::cos_sim(row3, q3) > 0.98);
    }

    #[test]
    fn property_quantized_within_block_range() {
        crate::util::prop::check("block range", 50, |rng| {
            let d = 64;
            let rows = 4;
            let scale = rng.uniform_in(0.01, 50.0);
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32 * scale).collect();
            let q = fake_quant(&x, rows, d, Format::Mxfp4);
            for (r, chunk) in q.chunks(MXFP_BLOCK).enumerate() {
                let orig = &x[r * MXFP_BLOCK..(r + 1) * MXFP_BLOCK];
                let a = amax(orig);
                for &v in chunk {
                    crate::prop_assert!(
                        v.abs() <= a * 2.0 + 1e-6,
                        "quantized {v} exceeds 2*amax {a}"
                    );
                }
            }
            Ok(())
        });
    }
}
