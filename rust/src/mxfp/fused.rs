//! Fused dual-MXFP quantization — the Rust mirror of the Pallas kernel
//! (`python/compile/kernels/quant_fused.py`, paper Algorithm 2).
//!
//! One pass over each row produces both precision copies and all scales
//! without materializing any intermediate buffer:
//!
//! * NVFP4 low copy — packed E2M1 nibbles + per-16 E4M3 scales,
//! * MXFP8 high copy — E4M3 codes + per-32 E8M0 exponents,
//! * per-token scale `S_q` (or coarser, per [`Granularity`]).
//!
//! This is the "MP" (fully fused) configuration of Table 6; the staged
//! baselines it is ablated against live in [`super::unfused`].

use super::block::Granularity;
use super::{e2m1, e8m0, fp8, pack, LOG2_E, MXFP_BLOCK, NVFP4_BLOCK};

/// Bit-level dual-quantized tensor ([rows, d] row-major source).
#[derive(Clone, Debug)]
pub struct DualQuantized {
    pub rows: usize,
    pub d: usize,
    /// Packed E2M1 codes, two per byte: [rows, d/2].
    pub packed_fp4: Vec<u8>,
    /// NVFP4 per-16-block scales as E4M3 codes: [rows, d/16].
    pub s4_codes: Vec<u8>,
    /// MXFP8 element codes (E4M3): [rows, d].
    pub fp8_codes: Vec<u8>,
    /// MXFP8 per-32-block E8M0 exponents: [rows, d/32].
    pub s8_codes: Vec<u8>,
    /// Outer quantization scale per row: [rows].
    pub sq: Vec<f32>,
}

impl DualQuantized {
    /// Total bytes of the quantized representation (memory-traffic model).
    pub fn quantized_bytes(&self) -> usize {
        self.packed_fp4.len()
            + self.s4_codes.len()
            + self.fp8_codes.len()
            + self.s8_codes.len()
            + self.sq.len() * 4
    }

    /// Dequantize rows `[r0, r1)` of the NVFP4 low-precision copy into
    /// `out` (`[(r1 - r0), d]`, row-major). This is the tile decoder the
    /// DMA attention loop and the paged KV cache run right before each
    /// matmul — no full-tensor materialization, no scratch allocation:
    /// nibbles are decoded straight from the packed plane through the
    /// E2M1 table (the unpack convention is `pack.rs`: low nibble =
    /// even element, high nibble = odd).
    pub fn decode_low_rows(&self, r0: usize, r1: usize, out: &mut [f32]) {
        let d = self.d;
        debug_assert!(r1 <= self.rows && out.len() >= (r1 - r0) * d);
        let lut4 = &e2m1::DECODE_LUT;
        let s4_lut = fp8::e4m3_table();
        for (rr, r) in (r0..r1).enumerate() {
            let sq = self.sq[r];
            let packed = &self.packed_fp4[r * d / 2..(r + 1) * d / 2];
            let orow = &mut out[rr * d..(rr + 1) * d];
            for b in 0..d / NVFP4_BLOCK {
                let s = s4_lut[self.s4_codes[r * d / NVFP4_BLOCK + b] as usize] * sq;
                let pb = &packed[b * (NVFP4_BLOCK / 2)..(b + 1) * (NVFP4_BLOCK / 2)];
                let ob = &mut orow[b * NVFP4_BLOCK..(b + 1) * NVFP4_BLOCK];
                crate::simd::nibble_lut_mul_scale(ob, pb, lut4, s);
            }
        }
    }

    /// Dequantize rows `[r0, r1)` of the MXFP8 high-precision copy into
    /// `out` (`[(r1 - r0), d]`, row-major). Table references are hoisted
    /// out of the loops so the per-element work is one indexed load and
    /// one multiply.
    pub fn decode_high_rows(&self, r0: usize, r1: usize, out: &mut [f32]) {
        let d = self.d;
        debug_assert!(r1 <= self.rows && out.len() >= (r1 - r0) * d);
        let lut8 = fp8::e4m3_table();
        let s8_lut = e8m0::table();
        for (rr, r) in (r0..r1).enumerate() {
            let sq = self.sq[r];
            let orow = &mut out[rr * d..(rr + 1) * d];
            for b in 0..d / MXFP_BLOCK {
                let s = s8_lut[self.s8_codes[r * d / MXFP_BLOCK + b] as usize] * sq;
                let codes = &self.fp8_codes[r * d + b * MXFP_BLOCK..r * d + (b + 1) * MXFP_BLOCK];
                let ob = &mut orow[b * MXFP_BLOCK..(b + 1) * MXFP_BLOCK];
                crate::simd::lut_mul_scale(ob, codes, lut8, s);
            }
        }
    }

    /// Dequantize the NVFP4 low-precision copy into `out` ([rows, d]).
    pub fn dequant_low(&self, out: &mut [f32]) {
        self.decode_low_rows(0, self.rows, out);
    }

    /// Dequantize the MXFP8 high-precision copy into `out` ([rows, d]).
    pub fn dequant_high(&self, out: &mut [f32]) {
        self.decode_high_rows(0, self.rows, out);
    }

    /// Append all rows of `other` (same `d`), keeping only the planes
    /// selected by `keep_low` / `keep_high`. The per-token scale plane is
    /// always kept (both copies share it). Because `S_q` is per-token,
    /// appending in any chunking is bit-identical to quantizing the whole
    /// matrix at once — the invariant behind the appendable KV cache
    /// ([`crate::kvquant`]).
    pub fn append_rows(&mut self, other: &DualQuantized, keep_low: bool, keep_high: bool) {
        assert_eq!(other.d, self.d, "row width mismatch");
        if keep_low {
            self.packed_fp4.extend_from_slice(&other.packed_fp4);
            self.s4_codes.extend_from_slice(&other.s4_codes);
        }
        if keep_high {
            self.fp8_codes.extend_from_slice(&other.fp8_codes);
            self.s8_codes.extend_from_slice(&other.s8_codes);
        }
        self.sq.extend_from_slice(&other.sq);
        self.rows += other.rows;
    }

    /// Drop all rows past `new_rows` from every resident plane (a plane
    /// an earlier [`Self::append_rows`] skipped stays empty —
    /// `Vec::truncate` past the end is a no-op). Because `S_q` is
    /// per-token, popping rows is exact: the surviving rows' bits are
    /// untouched, so truncating and re-appending the same tokens
    /// reproduces the original store bit for bit. This is the primitive
    /// under speculative-decode KV rollback ([`crate::kvquant`]).
    pub fn truncate_rows(&mut self, new_rows: usize) {
        assert!(
            new_rows <= self.rows,
            "truncate_rows {new_rows} > rows {}",
            self.rows
        );
        let d = self.d;
        self.packed_fp4.truncate(new_rows * d / 2);
        self.s4_codes.truncate(new_rows * d / NVFP4_BLOCK);
        self.fp8_codes.truncate(new_rows * d);
        self.s8_codes.truncate(new_rows * d / MXFP_BLOCK);
        self.sq.truncate(new_rows);
        self.rows = new_rows;
    }

    /// An empty store of width `d` ready for [`Self::append_rows`].
    pub fn empty(d: usize) -> DualQuantized {
        assert_eq!(d % MXFP_BLOCK, 0, "d={d} must be a multiple of 32");
        DualQuantized {
            rows: 0,
            d,
            packed_fp4: Vec::new(),
            s4_codes: Vec::new(),
            fp8_codes: Vec::new(),
            s8_codes: Vec::new(),
            sq: Vec::new(),
        }
    }
}

fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Fused dual quantization of a [rows, d] tensor (paper Algorithm 2).
///
/// `is_query` folds the base-2 softmax factor `log2(e)/sqrt(d)` into the
/// tensor before quantization (Step 1). `granularity` selects the S_q
/// scope (Step 2; `PerToken` is the paper's default).
pub fn dual_quant(
    x: &[f32],
    rows: usize,
    d: usize,
    is_query: bool,
    granularity: Granularity,
) -> DualQuantized {
    assert_eq!(x.len(), rows * d);
    assert_eq!(d % MXFP_BLOCK, 0, "d={d} must be a multiple of 32");
    let range = fp8::E4M3_MAX * e2m1::E2M1_MAX;
    let pre = if is_query {
        LOG2_E / (d as f32).sqrt()
    } else {
        1.0
    };

    // Coarse-granularity S_q values need a (cheap) amax prepass.
    let row_tile = 64usize;
    let tensor_amax = match granularity {
        Granularity::PerTensor => amax(x) * pre,
        _ => 0.0,
    };

    let mut out = DualQuantized {
        rows,
        d,
        packed_fp4: vec![0u8; rows * d / 2],
        s4_codes: vec![0u8; rows * d / NVFP4_BLOCK],
        fp8_codes: vec![0u8; rows * d],
        s8_codes: vec![0u8; rows * d / MXFP_BLOCK],
        sq: vec![0f32; rows],
    };

    let mut scaled = vec![0f32; d];
    let mut codes = vec![0u8; d];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        // Step 1 + Step 2: softmax pre-scale, then S_q.
        let row_amax = match granularity {
            Granularity::PerTensor => tensor_amax,
            Granularity::PerBlock => {
                let start = (r / row_tile) * row_tile;
                let end = (start + row_tile).min(rows);
                amax(&x[start * d..end * d]) * pre
            }
            Granularity::PerToken => amax(row) * pre,
        };
        let sq = (row_amax / range).max(1e-30);
        out.sq[r] = sq;
        let inv_sq = pre / sq;
        for (s, &v) in scaled.iter_mut().zip(row) {
            *s = v * inv_sq;
        }

        // Steps 3–5: NVFP4 branch (E4M3 block scale, E2M1 encode, pack).
        for b in 0..d / NVFP4_BLOCK {
            let blk = &scaled[b * NVFP4_BLOCK..(b + 1) * NVFP4_BLOCK];
            let s = fp8::quantize_e4m3(amax(blk) / e2m1::E2M1_MAX).max((-9.0f32).exp2());
            out.s4_codes[r * d / NVFP4_BLOCK + b] = fp8::encode_e4m3(s);
            let inv = 1.0 / s;
            for (i, &v) in blk.iter().enumerate() {
                codes[b * NVFP4_BLOCK + i] =
                    e2m1::encode((v * inv).clamp(-e2m1::E2M1_MAX, e2m1::E2M1_MAX));
            }
        }
        pack::pack_row(&codes, &mut out.packed_fp4[r * d / 2..(r + 1) * d / 2]);

        // Steps 6–7: MXFP8 branch (E8M0 exponent, E4M3 encode).
        for b in 0..d / MXFP_BLOCK {
            let blk = &scaled[b * MXFP_BLOCK..(b + 1) * MXFP_BLOCK];
            let (s, code) = e8m0::shared_scale(amax(blk), fp8::E4M3_EMAX);
            out.s8_codes[r * d / MXFP_BLOCK + b] = code;
            let inv = 1.0 / s;
            for (i, &v) in blk.iter().enumerate() {
                out.fp8_codes[r * d + b * MXFP_BLOCK + i] =
                    fp8::encode_e4m3((v * inv).clamp(-fp8::E4M3_MAX, fp8::E4M3_MAX));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::util::rng::Rng;

    fn randn(rows: usize, d: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * d).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn shapes() {
        let x = randn(32, 64, 1, 1.0);
        let q = dual_quant(&x, 32, 64, true, Granularity::PerToken);
        assert_eq!(q.packed_fp4.len(), 32 * 32);
        assert_eq!(q.s4_codes.len(), 32 * 4);
        assert_eq!(q.fp8_codes.len(), 32 * 64);
        assert_eq!(q.s8_codes.len(), 32 * 2);
        assert_eq!(q.sq.len(), 32);
    }

    #[test]
    fn high_copy_reconstructs_with_prescale() {
        let d = 64;
        let x = randn(32, d, 2, 1.0);
        let q = dual_quant(&x, 32, d, true, Granularity::PerToken);
        let mut high = vec![0f32; x.len()];
        q.dequant_high(&mut high);
        let pre = LOG2_E / (d as f32).sqrt();
        let target: Vec<f32> = x.iter().map(|v| v * pre).collect();
        assert!(metrics::cos_sim(&target, &high) > 0.999);
        let rel = metrics::rmse(&target, &high)
            / (target.iter().map(|v| v * v).sum::<f32>() / target.len() as f32).sqrt() as f64;
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn low_copy_coarser_than_high() {
        let x = randn(64, 64, 3, 2.0);
        let q = dual_quant(&x, 64, 64, false, Granularity::PerToken);
        let mut low = vec![0f32; x.len()];
        let mut high = vec![0f32; x.len()];
        q.dequant_low(&mut low);
        q.dequant_high(&mut high);
        let el = metrics::rmse(&x, &low);
        let eh = metrics::rmse(&x, &high);
        assert!(el > 2.0 * eh, "{el} vs {eh}");
    }

    #[test]
    fn key_path_identity_scale() {
        let x = randn(16, 32, 4, 1.0);
        let q = dual_quant(&x, 16, 32, false, Granularity::PerToken);
        let mut high = vec![0f32; x.len()];
        q.dequant_high(&mut high);
        assert!(metrics::cos_sim(&x, &high) > 0.999);
    }

    #[test]
    fn quantized_bytes_smaller_than_f32() {
        let x = randn(128, 128, 5, 1.0);
        let q = dual_quant(&x, 128, 128, false, Granularity::PerToken);
        // FP4(packed) + FP8 + scales must stay well under 2x f32 input
        // (it is ~1.6 bytes/elem vs 4 bytes/elem).
        assert!(q.quantized_bytes() < x.len() * 2);
    }

    #[test]
    fn granularities_agree_on_uniform_rows() {
        // If every row has the same amax the three granularities coincide.
        let d = 64;
        let mut x = randn(64, d, 6, 1.0);
        for r in 0..64 {
            // Force identical row amax.
            let row = &mut x[r * d..(r + 1) * d];
            let a = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let target = 3.0 / a;
            for v in row.iter_mut() {
                *v *= target;
            }
        }
        let qt = dual_quant(&x, 64, d, false, Granularity::PerToken);
        let qn = dual_quant(&x, 64, d, false, Granularity::PerTensor);
        assert_eq!(qt.packed_fp4, qn.packed_fp4);
        assert_eq!(qt.fp8_codes, qn.fp8_codes);
    }

    #[test]
    fn decode_rows_matches_full_dequant() {
        let (rows, d) = (24usize, 64usize);
        let x = randn(rows, d, 11, 1.5);
        let q = dual_quant(&x, rows, d, false, Granularity::PerToken);
        let mut low = vec![0f32; rows * d];
        let mut high = vec![0f32; rows * d];
        q.dequant_low(&mut low);
        q.dequant_high(&mut high);
        // Any sub-range decode must equal the corresponding slice of the
        // full decode, bit for bit.
        for (r0, r1) in [(0usize, 5usize), (5, 24), (7, 8), (16, 24)] {
            let n = r1 - r0;
            let mut lo = vec![0f32; n * d];
            let mut hi = vec![0f32; n * d];
            q.decode_low_rows(r0, r1, &mut lo);
            q.decode_high_rows(r0, r1, &mut hi);
            assert_eq!(lo, low[r0 * d..r1 * d].to_vec(), "low [{r0}, {r1})");
            assert_eq!(hi, high[r0 * d..r1 * d].to_vec(), "high [{r0}, {r1})");
        }
    }

    #[test]
    fn packed_direct_low_decode_matches_unpack_reference() {
        // The hot decoder reads nibbles straight from the packed plane;
        // it must equal the unpack-then-decode reference bit for bit for
        // every row range.
        let (rows, d) = (16usize, 96usize);
        let x = randn(rows, d, 21, 2.0);
        let q = dual_quant(&x, rows, d, false, Granularity::PerToken);
        for (r0, r1) in [(0usize, rows), (3, 9), (7, 8)] {
            let n = r1 - r0;
            let mut fast = vec![0f32; n * d];
            q.decode_low_rows(r0, r1, &mut fast);
            // Reference: unpack the nibbles, then per-element decode.
            let mut codes = vec![0u8; d];
            let mut reference = vec![0f32; n * d];
            for (rr, r) in (r0..r1).enumerate() {
                crate::mxfp::pack::unpack_row(
                    &q.packed_fp4[r * d / 2..(r + 1) * d / 2], &mut codes);
                for b in 0..d / NVFP4_BLOCK {
                    let s = fp8::decode_e4m3(q.s4_codes[r * d / NVFP4_BLOCK + b]) * q.sq[r];
                    for i in 0..NVFP4_BLOCK {
                        reference[rr * d + b * NVFP4_BLOCK + i] =
                            e2m1::decode(codes[b * NVFP4_BLOCK + i]) * s;
                    }
                }
            }
            assert_eq!(fast, reference, "[{r0}, {r1})");
        }
    }

    #[test]
    fn append_rows_chunking_invariant() {
        // Appending in chunks must be bit-identical to one-shot
        // quantization (per-token S_q).
        let (rows, d) = (21usize, 32usize);
        let x = randn(rows, d, 12, 2.0);
        let bulk = dual_quant(&x, rows, d, false, Granularity::PerToken);
        let mut acc = DualQuantized::empty(d);
        for (r0, r1) in [(0usize, 9usize), (9, 10), (10, 21)] {
            let chunk = dual_quant(&x[r0 * d..r1 * d], r1 - r0, d, false,
                                   Granularity::PerToken);
            acc.append_rows(&chunk, true, true);
        }
        assert_eq!(acc.rows, rows);
        assert_eq!(acc.packed_fp4, bulk.packed_fp4);
        assert_eq!(acc.s4_codes, bulk.s4_codes);
        assert_eq!(acc.fp8_codes, bulk.fp8_codes);
        assert_eq!(acc.s8_codes, bulk.s8_codes);
        assert_eq!(acc.sq, bulk.sq);
    }

    #[test]
    fn truncate_rows_is_exact_pop() {
        // Truncating rows then re-appending the same tokens must equal
        // never having appended-and-rolled-back at all, bit for bit —
        // the invariant speculative-decode rollback rests on.
        let (rows, d) = (13usize, 32usize);
        let x = randn(rows, d, 14, 1.5);
        let full = dual_quant(&x, rows, d, false, Granularity::PerToken);
        let mut q = full.clone();
        q.truncate_rows(9);
        assert_eq!(q.rows, 9);
        assert_eq!(q.packed_fp4, full.packed_fp4[..9 * d / 2].to_vec());
        assert_eq!(q.sq, full.sq[..9].to_vec());
        let tail = dual_quant(&x[9 * d..], rows - 9, d, false, Granularity::PerToken);
        q.append_rows(&tail, true, true);
        assert_eq!(q.packed_fp4, full.packed_fp4);
        assert_eq!(q.s4_codes, full.s4_codes);
        assert_eq!(q.fp8_codes, full.fp8_codes);
        assert_eq!(q.s8_codes, full.s8_codes);
        assert_eq!(q.sq, full.sq);
        // Truncation on a partial-plane store skips the absent planes.
        let mut low_only = DualQuantized::empty(d);
        low_only.append_rows(&full, true, false);
        low_only.truncate_rows(4);
        assert_eq!(low_only.rows, 4);
        assert!(low_only.fp8_codes.is_empty());
        assert_eq!(low_only.packed_fp4, full.packed_fp4[..4 * d / 2].to_vec());
        // Truncate to 0 empties everything.
        let mut z = full.clone();
        z.truncate_rows(0);
        assert_eq!(z.rows, 0);
        assert!(z.sq.is_empty() && z.packed_fp4.is_empty());
    }

    #[test]
    fn append_rows_partial_planes() {
        let (rows, d) = (8usize, 32usize);
        let x = randn(rows, d, 13, 1.0);
        let q = dual_quant(&x, rows, d, false, Granularity::PerToken);
        let mut low_only = DualQuantized::empty(d);
        low_only.append_rows(&q, true, false);
        assert_eq!(low_only.fp8_codes.len(), 0);
        assert_eq!(low_only.packed_fp4, q.packed_fp4);
        assert_eq!(low_only.quantized_bytes(),
                   q.packed_fp4.len() + q.s4_codes.len() + rows * 4);
        let mut high_only = DualQuantized::empty(d);
        high_only.append_rows(&q, false, true);
        assert_eq!(high_only.packed_fp4.len(), 0);
        let mut out = vec![0f32; rows * d];
        high_only.decode_high_rows(0, rows, &mut out);
        let mut expect = vec![0f32; rows * d];
        q.dequant_high(&mut expect);
        assert_eq!(out, expect);
    }

    #[test]
    fn property_reconstruction_error_bounds() {
        crate::util::prop::check("dual quant bounds", 30, |rng| {
            let d = crate::util::prop::gen::dim_multiple_of(rng, 32, 32, 128);
            let rows = 8;
            let scale = rng.uniform_in(0.01, 100.0);
            let x: Vec<f32> =
                (0..rows * d).map(|_| rng.normal() as f32 * scale).collect();
            let q = dual_quant(&x, rows, d, false, Granularity::PerToken);
            let mut low = vec![0f32; x.len()];
            let mut high = vec![0f32; x.len()];
            q.dequant_low(&mut low);
            q.dequant_high(&mut high);
            let nx = (x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt() + 1e-9;
            let el = x.iter().zip(&low).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
            let eh = x.iter().zip(&high).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
            crate::prop_assert!(el / nx < 0.25, "low rel err {}", el / nx);
            crate::prop_assert!(eh / nx < 0.07, "high rel err {}", eh / nx);
            Ok(())
        });
    }
}
