//! Analytical B200 cost model.
//!
//! This testbed has no Blackwell GPU (DESIGN.md §4), so the paper's
//! *latency* tables are projected through a roofline-style model whose
//! inputs are structural quantities we measure exactly in Rust — tile
//! counts per precision class, bytes moved per format, operator pass
//! counts and kernel launches — combined with public B200 throughput
//! numbers. The model is deliberately simple and fully unit-tested; its
//! job is to preserve *who wins and by roughly what factor*, not
//! absolute microseconds.
//!
//! Sources for the constants: NVIDIA Blackwell whitepaper (ref. [12] of
//! the paper) dense tensor-core rates and HBM3e bandwidth.

use crate::attention::TileConfig;
use crate::mxfp::block::Format;

/// Element precision classes used on the tensor cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Fp4,
    Fp8,
    Bf16,
}

#[derive(Clone, Debug)]
pub struct B200Model {
    /// HBM3e bandwidth, bytes/s.
    pub hbm_bps: f64,
    /// Dense tensor-core throughput per precision, FLOP/s.
    pub fp4_flops: f64,
    pub fp8_flops: f64,
    pub bf16_flops: f64,
    /// Per-kernel-launch overhead (eager dispatch), seconds.
    pub launch_s: f64,
    /// Number of SMs (for tile-parallelism occupancy).
    pub sms: usize,
    /// Shared memory per SM, bytes. Tiles whose working set exceeds this
    /// spill the score tile S to HBM (the paper's "larger block size is
    /// less efficient" observation for the 256 configuration).
    pub smem_bytes: f64,
}

impl Default for B200Model {
    fn default() -> Self {
        B200Model {
            hbm_bps: 8.0e12,       // ~8 TB/s HBM3e
            fp4_flops: 9.0e15,     // dense FP4
            fp8_flops: 4.5e15,     // dense FP8
            bf16_flops: 2.25e15,   // dense BF16
            launch_s: 8.0e-6,      // eager per-op dispatch + launch
            sms: 148,
            smem_bytes: 228.0 * 1024.0,
        }
    }
}

impl B200Model {
    pub fn rate(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp4 => self.fp4_flops,
            Precision::Fp8 => self.fp8_flops,
            Precision::Bf16 => self.bf16_flops,
        }
    }

    pub fn bits(p: Precision) -> f64 {
        match p {
            Precision::Fp4 => 4.0,
            Precision::Fp8 => 8.0,
            Precision::Bf16 => 16.0,
        }
    }

    /// Latency of one attention tile (bm x bn over head dim d):
    /// max(compute, memory) roofline.
    fn tile_s(&self, bm: usize, bn: usize, d: usize, p: Precision) -> f64 {
        // S = Q K^T (2*bm*bn*d) + P V (2*bm*bn*d).
        let flops = 4.0 * bm as f64 * bn as f64 * d as f64;
        // Read K tile at element precision + V tile bf16 (Q stays in
        // registers/SMEM across j); write nothing (online softmax)...
        let mut bytes = bn as f64 * d as f64 * (Self::bits(p) + 16.0) / 8.0;
        // ...unless the working set exceeds shared memory: then the S
        // tile (f32) spills to HBM and is read back for the PV matmul.
        let footprint = (bm * bn) as f64 * 4.0
            + (bm + bn) as f64 * d as f64 * Self::bits(p) / 8.0
            + bn as f64 * d as f64 * 2.0;
        if footprint > self.smem_bytes {
            bytes += 2.0 * (bm * bn) as f64 * 4.0;
        }
        (flops / self.rate(p)).max(bytes / self.hbm_bps)
    }

    /// Occupancy efficiency as a function of query-tile size: fewer,
    /// larger tiles leave SMs idle (the paper's Table 4 observation that
    /// the 256 block-scale config loses throughput).
    fn occupancy(&self, n_query_tiles: usize, heads_x_batch: usize) -> f64 {
        let blocks = (n_query_tiles * heads_x_batch) as f64;
        let waves = (blocks / self.sms as f64).ceil();
        (blocks / self.sms as f64) / waves
    }

    /// Project the attention kernel latency for a tile-level precision
    /// schedule (the DMA kernel or a fixed-format kernel).
    ///
    /// `causal_aware` kernels skip upper-triangle tiles entirely (the
    /// DMA phase structure); the eager fixed-format baselines compute
    /// the full rectangle and mask.
    pub fn attention_latency_s(
        &self,
        l: usize,
        d: usize,
        heads_x_batch: usize,
        cfg: &TileConfig,
        low: Precision,
        high: Precision,
        causal_aware: bool,
    ) -> f64 {
        let nq = l / cfg.bm;
        let nk = l / cfg.bn;
        let mut total = 0.0f64;
        for i in 0..nq {
            let frontier = (i * cfg.bm + cfg.bm - 1) as i64;
            for j in 0..nk {
                let t0 = (j * cfg.bn) as i64;
                let t1 = (j * cfg.bn + cfg.bn - 1) as i64;
                if causal_aware && cfg.causal && t0 > frontier {
                    continue; // skipped entirely by the phase structure
                }
                let in_diag = cfg.diag > 0
                    && t1 >= frontier - (cfg.diag as i64 - 1)
                    && t0 <= frontier;
                let in_sink = cfg.sink > 0 && (j * cfg.bn) < cfg.sink;
                let p = if in_diag || in_sink { high } else { low };
                total += self.tile_s(cfg.bm, cfg.bn, d, p);
            }
        }
        total * heads_x_batch as f64 / (self.sms as f64)
            / self.occupancy(nq, heads_x_batch).max(1e-6)
    }

    /// Project the quantization pipeline latency from measured structure:
    /// number of whole-tensor passes and kernel launches (Tables 6/7).
    pub fn quant_latency_s(&self, rows: usize, d: usize, passes: usize, launches: usize) -> f64 {
        // Each pass streams the tensor once (read + write at fp16).
        let bytes_per_pass = 2.0 * rows as f64 * d as f64 * 2.0;
        passes as f64 * bytes_per_pass / self.hbm_bps + launches as f64 * self.launch_s
    }
}

/// Precision pair for a fixed-format baseline.
pub fn format_precision(f: Format) -> Precision {
    match f {
        Format::Mxfp4 | Format::Nvfp4 => Precision::Fp4,
        Format::Mxfp8E4m3 | Format::Mxfp8E5m2 => Precision::Fp8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(diag: usize, sink: usize, bm: usize) -> TileConfig {
        TileConfig { bm, bn: bm, diag, sink, causal: true }
    }

    const HXB: usize = 32 * 8; // heads x batch used in the tables

    #[test]
    fn table4_ordering_holds() {
        let m = B200Model::default();
        let l = 8192;
        let d = 128;
        // Fixed-format baselines: not causal-aware (full rectangle).
        let base = |p: Precision| {
            m.attention_latency_s(l, d, HXB, &cfg(0, 0, 64), p, p, false)
        };
        let mxfp4 = base(Precision::Fp4);
        let mxfp8 = base(Precision::Fp8);
        // Ours: causal-aware diagonal kernel, 128/128.
        let ours128 = m.attention_latency_s(
            l, d, HXB, &cfg(128, 128, 64), Precision::Fp4, Precision::Fp8, true);
        // Ours with 256 tiles: fewer, larger blocks -> worse occupancy.
        let ours256 = m.attention_latency_s(
            l, d, HXB, &cfg(256, 256, 256), Precision::Fp4, Precision::Fp8, true);

        assert!(ours128 < mxfp4, "ours {ours128} !< mxfp4 {mxfp4}");
        assert!(mxfp4 < mxfp8, "mxfp4 {mxfp4} !< mxfp8 {mxfp8}");
        assert!(ours128 < ours256, "128 {ours128} !< 256 {ours256}");
        // Paper: ours-128 7.1ms vs mxfp4 12.5ms (~1.76x); accept 1.3-3x.
        let speedup = mxfp4 / ours128;
        assert!((1.3..3.0).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn high_precision_window_costs_little() {
        let m = B200Model::default();
        let l = 8192;
        let all_low = m.attention_latency_s(
            l, 128, HXB, &cfg(0, 0, 64), Precision::Fp4, Precision::Fp8, true);
        let dma = m.attention_latency_s(
            l, 128, HXB, &cfg(128, 128, 64), Precision::Fp4, Precision::Fp8, true);
        // 2.3% high tiles must cost < 10% extra.
        assert!(dma < all_low * 1.10, "{dma} vs {all_low}");
        assert!(dma > all_low, "high tiles are not free");
    }

    #[test]
    fn launch_overhead_dominates_unfused_small_tensors(){
        let m = B200Model::default();
        // L=2k quantization: eager pipeline ~20 passes/launches vs 1.
        let unfused = m.quant_latency_s(2048, 128, 20, 20);
        let fused = m.quant_latency_s(2048, 128, 1, 1);
        let speedup = unfused / fused;
        assert!(speedup > 10.0, "speedup {speedup}");
    }

    #[test]
    fn quant_latency_scales_with_rows() {
        let m = B200Model::default();
        let a = m.quant_latency_s(2048, 128, 1, 1);
        let b = m.quant_latency_s(8192, 128, 1, 1);
        assert!(b > a);
    }

    #[test]
    fn occupancy_penalty_for_big_tiles() {
        let m = B200Model::default();
        // 8192/64 = 128 query tiles * 256 = many waves, good occupancy.
        let small = m.occupancy(128, 256);
        // 8192/256 = 32 query tiles * 8 = 256 blocks on 148 SMs: 2 waves
        // of 86% average occupancy.
        let big = m.occupancy(32, 8);
        assert!(small >= big, "{small} vs {big}");
    }

    #[test]
    fn memory_bound_at_tiny_compute() {
        let m = B200Model::default();
        // A 1x1 tile is trivially memory-bound: time == bytes/bw.
        let t = m.tile_s(1, 1, 64, Precision::Fp4);
        let bytes = 64.0 * (4.0 + 16.0) / 8.0;
        assert!((t - bytes / m.hbm_bps).abs() / t < 1e-9);
    }
}
