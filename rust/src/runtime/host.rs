//! Host fallback [`ModelBackend`]: the pure-Rust CPU model mirror behind
//! the same interface as the PJRT runtime. Lets the whole coordinator
//! stack (scheduler, batcher, server, examples) run and test without
//! artifacts, and cross-checks PJRT outputs in integration tests.

use super::{ModelBackend, PrefillOut};
use crate::config::ModelConfig;
use crate::kvcache::{SlotCache, SlotKv};
use crate::model::{AttnMode, CpuModel, KvState};

pub struct HostBackend {
    pub model: CpuModel,
    slots: SlotCache,
    cache_len: usize,
    buckets: Vec<usize>,
}

impl HostBackend {
    pub fn new(model: CpuModel, cache_len: usize) -> HostBackend {
        let cfg = model.cfg.clone();
        HostBackend {
            slots: SlotCache::new(cfg.n_layers, cfg.n_kv_heads, cache_len, cfg.d_head),
            model,
            cache_len,
            buckets: vec![1, 2, 4],
        }
    }

    /// Deterministic random-weight backend used across tests.
    pub fn for_tests() -> HostBackend {
        let cfg = crate::model::test_config();
        let w = crate::model::random_weights(&cfg, 42);
        HostBackend::new(CpuModel::new(cfg, w).unwrap(), 96)
    }

    fn cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    /// SlotKv (flat [NL, H, C, Dh]) -> KvState tensors.
    fn slot_to_state(&self, slot: &SlotKv) -> KvState {
        let cfg = self.cfg();
        let mut st = KvState::new(cfg, self.cache_len);
        let (c, dh) = (self.cache_len, cfg.d_head);
        for li in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let base = (li * cfg.n_kv_heads + h) * c * dh;
                st.k[li][h].data.copy_from_slice(&slot.k[base..base + c * dh]);
                st.v[li][h].data.copy_from_slice(&slot.v[base..base + c * dh]);
            }
        }
        st.len = slot.pos;
        st
    }

    fn state_to_slot(&self, st: &KvState) -> SlotKv {
        let cfg = self.cfg();
        let mut slot = self.slots.empty_slot();
        let (c, dh) = (self.cache_len, cfg.d_head);
        for li in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let base = (li * cfg.n_kv_heads + h) * c * dh;
                slot.k[base..base + c * dh].copy_from_slice(&st.k[li][h].data);
                slot.v[base..base + c * dh].copy_from_slice(&st.v[li][h].data);
            }
        }
        slot.pos = st.len;
        slot
    }
}

impl ModelBackend for HostBackend {
    fn prefill(&mut self, tokens: &[i32], dma: bool) -> crate::Result<PrefillOut> {
        let mode = if dma { AttnMode::Dma } else { AttnMode::Native };
        let mut kv = KvState::new(self.cfg(), self.cache_len);
        let logits = self.model.prefill(tokens, mode, &mut kv)?;
        let last = logits.row(tokens.len() - 1).to_vec();
        Ok(PrefillOut { last_logits: last, slot: self.state_to_slot(&kv) })
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        slots: &mut [Option<&mut SlotKv>],
    ) -> crate::Result<Vec<f32>> {
        let vocab = self.cfg().vocab;
        let mut out = vec![0f32; slots.len() * vocab];
        for (i, slot) in slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            let mut st = self.slot_to_state(s);
            let logits = self.model.decode_step(tokens[i], &mut st)?;
            out[i * vocab..(i + 1) * vocab].copy_from_slice(&logits);
            **s = self.state_to_slot(&st);
        }
        Ok(out)
    }

    fn eval_logits(
        &mut self,
        tokens: &[i32],
        b: usize,
        l: usize,
        dma: bool,
    ) -> crate::Result<Vec<f32>> {
        let mode = if dma { AttnMode::Dma } else { AttnMode::Native };
        let vocab = self.cfg().vocab;
        let mut out = vec![0f32; b * l * vocab];
        for bi in 0..b {
            let mut kv = KvState::new(self.cfg(), l);
            let logits = self
                .model
                .prefill(&tokens[bi * l..(bi + 1) * l], mode, &mut kv)?;
            out[bi * l * vocab..(bi + 1) * l * vocab].copy_from_slice(&logits.data);
        }
        Ok(out)
    }

    fn vocab(&self) -> usize {
        self.cfg().vocab
    }

    fn cache_len(&self) -> usize {
        self.cache_len
    }

    fn decode_buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn name(&self) -> &'static str {
        "host-cpu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_then_decode_matches_cpu_model() {
        let mut be = HostBackend::for_tests();
        let toks: Vec<i32> = (0..16).map(|i| ((i * 7) % 60) + 1).collect();
        let out = be.prefill(&toks, false).unwrap();
        assert_eq!(out.last_logits.len(), 64);
        assert_eq!(out.slot.pos, 16);

        // Direct CPU path for comparison.
        let cfg = crate::model::test_config();
        let w = crate::model::random_weights(&cfg, 42);
        let m = CpuModel::new(cfg, w).unwrap();
        let mut kv = KvState::new(&m.cfg, 96);
        let lg = m.prefill(&toks, AttnMode::Native, &mut kv).unwrap();
        for (a, b) in out.last_logits.iter().zip(lg.row(15)) {
            assert!((a - b).abs() < 1e-5);
        }

        // Decode continues correctly through slot round-trips.
        let mut slot = out.slot;
        let logits = be.decode(&[7], &mut [Some(&mut slot)]).unwrap();
        let l2 = m.decode_step(7, &mut kv).unwrap();
        for (a, b) in logits.iter().zip(&l2) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(slot.pos, 17);
    }

    #[test]
    fn batch_decode_with_padding_slots() {
        let mut be = HostBackend::for_tests();
        let o1 = be.prefill(&[1, 2, 3, 4], false).unwrap();
        let mut s1 = o1.slot;
        let logits = be.decode(&[9, 0], &mut [Some(&mut s1), None]).unwrap();
        assert_eq!(logits.len(), 2 * 64);
        assert_eq!(s1.pos, 5);
    }

    #[test]
    fn eval_logits_shape() {
        let mut be = HostBackend::for_tests();
        let toks: Vec<i32> = (0..2 * 8).map(|i| (i % 60) as i32 + 1).collect();
        let lg = be.eval_logits(&toks, 2, 8, false).unwrap();
        assert_eq!(lg.len(), 2 * 8 * 64);
    }
}
