//! Host fallback [`ModelBackend`]: the pure-Rust CPU model mirror behind
//! the same interface as the PJRT runtime. Lets the whole coordinator
//! stack (scheduler, batcher, server, examples) run and test without
//! artifacts, and cross-checks PJRT outputs in integration tests.
//!
//! Prefill streams: each [`ModelBackend::prefill_chunk`] call runs one
//! prompt slice through [`CpuModel::prefill_chunk`] (f32 working cache)
//! or [`CpuModel::prefill_chunk_quant`] (quantize-on-append into paged
//! stores — no f32 staging slot ever exists for quantized formats).

use super::{ModelBackend, PrefillOut, PrefillSeq, PrefillState};
use crate::config::ModelConfig;
use crate::kvcache::{SeqKv, SlotCache, SlotKv};
use crate::kvquant::{KvQuantConfig, QuantSlotKv};
use crate::metrics::KvPageStats;
use crate::model::{AttnMode, CpuModel, KvState};

pub struct HostBackend {
    pub model: CpuModel,
    slots: SlotCache,
    cache_len: usize,
    buckets: Vec<usize>,
    /// Cumulative page-decode counters from quantized-cache prefills and
    /// decodes.
    kv_stats: KvPageStats,
    /// Worker threads for the per-sequence decode fan-out (the model's
    /// per-kv-head fan-out uses `model.threads`; both are set together
    /// through [`ModelBackend::set_perf`]).
    threads: usize,
    /// Per-slot decoded-page cache budget applied to quantized slots
    /// opened by this backend.
    decoded_cache_bytes: usize,
}

impl HostBackend {
    pub fn new(model: CpuModel, cache_len: usize) -> HostBackend {
        let cfg = model.cfg.clone();
        HostBackend {
            slots: SlotCache::new(cfg.n_layers, cfg.n_kv_heads, cache_len, cfg.d_head),
            model,
            cache_len,
            buckets: vec![1, 2, 4],
            kv_stats: KvPageStats::default(),
            threads: 1,
            decoded_cache_bytes: crate::kvquant::DECODED_CACHE_BYTES,
        }
    }

    /// Builder-style perf-knob override (tests/benches; the engine goes
    /// through [`ModelBackend::set_perf`]).
    pub fn with_perf(mut self, threads: usize, decoded_cache_bytes: usize) -> HostBackend {
        self.set_perf(threads, decoded_cache_bytes);
        self
    }

    /// Deterministic random-weight backend used across tests.
    pub fn for_tests() -> HostBackend {
        let cfg = crate::model::test_config();
        let w = crate::model::random_weights(&cfg, 42);
        HostBackend::new(CpuModel::new(cfg, w).unwrap(), 96)
    }

    /// Same model/weights as [`Self::for_tests`] with a caller-chosen
    /// cache length (benches that need room for long shared prompts).
    pub fn for_tests_with_cache(cache_len: usize) -> HostBackend {
        let cfg = crate::model::test_config();
        let w = crate::model::random_weights(&cfg, 42);
        HostBackend::new(CpuModel::new(cfg, w).unwrap(), cache_len)
    }

    fn cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    /// KvState (any capacity >= its live rows) -> padded batch SlotKv.
    fn state_to_slot(&self, st: &KvState) -> SlotKv {
        state_to_slot(&self.slots, self.cfg(), self.cache_len, st)
    }
}

/// SlotKv (flat [NL, H, C, Dh]) -> KvState tensors. Free function so the
/// parallel decode fan-out can call it per sequence without borrowing the
/// whole backend.
fn slot_to_state(cfg: &ModelConfig, cache_len: usize, slot: &SlotKv) -> KvState {
    let mut st = KvState::new(cfg, cache_len);
    let (c, dh) = (cache_len, cfg.d_head);
    for li in 0..cfg.n_layers {
        for h in 0..cfg.n_kv_heads {
            let base = (li * cfg.n_kv_heads + h) * c * dh;
            st.k[li][h].data.copy_from_slice(&slot.k[base..base + c * dh]);
            st.v[li][h].data.copy_from_slice(&slot.v[base..base + c * dh]);
        }
    }
    st.len = slot.pos;
    st
}

/// KvState (any capacity >= its live rows) -> padded batch SlotKv.
fn state_to_slot(layout: &SlotCache, cfg: &ModelConfig, cache_len: usize, st: &KvState) -> SlotKv {
    let mut slot = layout.empty_slot();
    let (c, dh) = (cache_len, cfg.d_head);
    let live = st.len.min(c);
    for li in 0..cfg.n_layers {
        for h in 0..cfg.n_kv_heads {
            let base = (li * cfg.n_kv_heads + h) * c * dh;
            slot.k[base..base + live * dh]
                .copy_from_slice(&st.k[li][h].data[..live * dh]);
            slot.v[base..base + live * dh]
                .copy_from_slice(&st.v[li][h].data[..live * dh]);
        }
    }
    slot.pos = st.len;
    slot
}

impl ModelBackend for HostBackend {
    fn begin_prefill(
        &mut self,
        tokens: &[i32],
        dma: bool,
        quant: Option<&KvQuantConfig>,
        seed: Option<QuantSlotKv>,
    ) -> crate::Result<PrefillSeq> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(
            tokens.len() <= self.cache_len,
            "prompt {} exceeds cache {}",
            tokens.len(),
            self.cache_len
        );
        let cfg = self.cfg().clone();
        let (state, done) = match quant {
            Some(qcfg) => {
                let mut slot = match seed {
                    Some(s) => {
                        anyhow::ensure!(
                            s.pos < tokens.len(),
                            "seed covers the whole prompt ({} >= {})",
                            s.pos,
                            tokens.len()
                        );
                        s
                    }
                    None => QuantSlotKv::new(
                        qcfg.clone(),
                        cfg.n_layers,
                        cfg.n_kv_heads,
                        cfg.d_head,
                    ),
                };
                slot.set_decoded_budget(self.decoded_cache_bytes);
                let done = slot.pos;
                (PrefillState::Quant(slot), done)
            }
            None => {
                anyhow::ensure!(seed.is_none(), "prefix seeding requires a quantized cache");
                // Prompt-length working cache — the cache-length f32
                // staging slot is gone; padding happens once at finish.
                (PrefillState::F32(KvState::new(&cfg, tokens.len())), 0)
            }
        };
        Ok(PrefillSeq {
            tokens: tokens.to_vec(),
            dma,
            done,
            last_logits: Vec::new(),
            state,
        })
    }

    fn prefill_chunk(&mut self, seq: &mut PrefillSeq, max_tokens: usize) -> crate::Result<()> {
        anyhow::ensure!(max_tokens > 0, "zero-token prefill chunk");
        let n = max_tokens.min(seq.remaining());
        if n == 0 {
            return Ok(());
        }
        let mode = if seq.dma { AttnMode::Dma } else { AttnMode::Native };
        let chunk = &seq.tokens[seq.done..seq.done + n];
        let logits = match &mut seq.state {
            PrefillState::F32(kv) => self.model.prefill_chunk(chunk, mode, kv)?,
            PrefillState::Quant(kv) => {
                self.model.prefill_chunk_quant(chunk, mode, kv, &mut self.kv_stats)?
            }
            PrefillState::Deferred => {
                anyhow::bail!("host backend does not defer prefill")
            }
        };
        seq.last_logits = logits.row(n - 1).to_vec();
        seq.done += n;
        Ok(())
    }

    fn finish_prefill(&mut self, seq: PrefillSeq) -> crate::Result<PrefillOut> {
        anyhow::ensure!(seq.is_done(), "prefill incomplete ({}/{})",
                        seq.done, seq.tokens.len());
        let kv = match seq.state {
            PrefillState::F32(st) => SeqKv::F32(self.state_to_slot(&st)),
            PrefillState::Quant(q) => SeqKv::Quant(q),
            PrefillState::Deferred => anyhow::bail!("host backend does not defer prefill"),
        };
        Ok(PrefillOut { last_logits: seq.last_logits, kv })
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        slots: &mut [Option<&mut SeqKv>],
    ) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() >= slots.len(),
            "decode batch mismatch: {} tokens for {} slots",
            tokens.len(),
            slots.len()
        );
        let vocab = self.cfg().vocab;
        let mut out = vec![0f32; slots.len() * vocab];

        // One work item per live sequence, each owning its slot and its
        // disjoint logits row — the batch fans across the worker threads
        // (intra-step parallelism; identical results at any count, since
        // sequences are independent). Per-item page stats merge after.
        struct SeqWork<'a> {
            token: i32,
            slot: &'a mut SeqKv,
            out: &'a mut [f32],
            stats: KvPageStats,
            result: crate::Result<()>,
        }
        let mut items: Vec<SeqWork<'_>> = Vec::new();
        for ((slot, row), &token) in slots
            .iter_mut()
            .zip(out.chunks_mut(vocab))
            .zip(tokens)
        {
            if let Some(s) = slot {
                items.push(SeqWork {
                    token,
                    slot: &mut **s,
                    out: row,
                    stats: KvPageStats::default(),
                    result: Ok(()),
                });
            }
        }
        let model = &self.model;
        let layout = &self.slots;
        let cache_len = self.cache_len;
        // One thread budget split across the two fan-out levels: `outer`
        // workers over sequences, each allotted `inner` for the model's
        // per-kv-head fan-out — the product never exceeds the budget
        // (a single-sequence batch gives the whole budget to the heads).
        let outer = self.threads.max(1).min(items.len().max(1));
        let inner = (self.threads.max(1) / outer).max(1);
        crate::util::pool::par_items(&mut items, outer, |w| {
            let step = |w: &mut SeqWork<'_>| -> crate::Result<()> {
                let logits = match &mut *w.slot {
                    SeqKv::F32(sl) => {
                        let mut st = slot_to_state(&model.cfg, cache_len, sl);
                        let logits = model.decode_step_with_threads(w.token, &mut st, inner)?;
                        *sl = state_to_slot(layout, &model.cfg, cache_len, &st);
                        logits
                    }
                    SeqKv::Quant(qs) => {
                        // Mirror the f32 path's capacity guard (KvState
                        // checks this internally; the paged store grows
                        // on demand).
                        anyhow::ensure!(
                            qs.pos < cache_len,
                            "cache full ({}/{})",
                            qs.pos,
                            cache_len
                        );
                        model.decode_step_paged_with_threads(
                            w.token, qs, &mut w.stats, inner)?
                    }
                };
                w.out.copy_from_slice(&logits);
                Ok(())
            };
            w.result = step(w);
        });
        // Merge every item's page counters before surfacing any error:
        // items after a failing one still ran (par_items completes the
        // whole batch), and their decodes must not vanish from the stats.
        let mut first_err: crate::Result<()> = Ok(());
        for w in items {
            self.kv_stats.merge(w.stats);
            if first_err.is_ok() {
                if let Err(e) = w.result {
                    first_err = Err(e);
                }
            }
        }
        first_err?;
        Ok(out)
    }

    fn decode_multi(
        &mut self,
        chains: &[Vec<i32>],
        slots: &mut [Option<&mut SeqKv>],
    ) -> crate::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(chains.len() == slots.len(), "chains/slots length mismatch");
        let vocab = self.cfg().vocab;

        // One work item per live chain; chains fan across the worker
        // threads like single-token batches do, and each chain walks its
        // tokens serially through the exact decode-step kernels — so the
        // logits are bit-identical to the default per-token replay (and
        // to non-speculative decode), just without the per-token batch
        // assembly and slot round-trips.
        struct ChainWork<'a> {
            chain: &'a [i32],
            slot: &'a mut SeqKv,
            out: Vec<f32>,
            stats: KvPageStats,
            result: crate::Result<()>,
        }
        let mut items: Vec<ChainWork<'_>> = Vec::new();
        for (slot, chain) in slots.iter_mut().zip(chains) {
            if let Some(s) = slot {
                items.push(ChainWork {
                    chain,
                    slot: &mut **s,
                    out: Vec::with_capacity(chain.len() * vocab),
                    stats: KvPageStats::default(),
                    result: Ok(()),
                });
            }
        }
        let model = &self.model;
        let layout = &self.slots;
        let cache_len = self.cache_len;
        let outer = self.threads.max(1).min(items.len().max(1));
        let inner = (self.threads.max(1) / outer).max(1);
        crate::util::pool::par_items(&mut items, outer, |w| {
            let step = |w: &mut ChainWork<'_>| -> crate::Result<()> {
                match &mut *w.slot {
                    SeqKv::F32(sl) => {
                        // One state round-trip for the whole chain; the
                        // conversions are pure copies, so per-token
                        // round-trips would produce the same bits.
                        let mut st = slot_to_state(&model.cfg, cache_len, sl);
                        for &t in w.chain {
                            let logits = model.decode_step_with_threads(t, &mut st, inner)?;
                            w.out.extend_from_slice(&logits);
                        }
                        *sl = state_to_slot(layout, &model.cfg, cache_len, &st);
                    }
                    SeqKv::Quant(qs) => {
                        for &t in w.chain {
                            anyhow::ensure!(
                                qs.pos < cache_len,
                                "cache full ({}/{})",
                                qs.pos,
                                cache_len
                            );
                            let logits = model.decode_step_paged_with_threads(
                                t, qs, &mut w.stats, inner)?;
                            w.out.extend_from_slice(&logits);
                        }
                    }
                }
                Ok(())
            };
            w.result = step(w);
        });
        let mut first_err: crate::Result<()> = Ok(());
        let mut rows = Vec::with_capacity(items.len());
        for w in items {
            self.kv_stats.merge(w.stats);
            if first_err.is_ok() {
                if let Err(e) = w.result {
                    first_err = Err(e);
                }
            }
            rows.push(w.out);
        }
        first_err?;
        // Re-expand to one row vector per input position (None slots get
        // an empty row), matching the trait contract.
        let mut out = Vec::with_capacity(chains.len());
        let mut it = rows.into_iter();
        for slot in slots.iter() {
            out.push(if slot.is_some() { it.next().unwrap() } else { Vec::new() });
        }
        Ok(out)
    }

    fn eval_logits(
        &mut self,
        tokens: &[i32],
        b: usize,
        l: usize,
        dma: bool,
    ) -> crate::Result<Vec<f32>> {
        let mode = if dma { AttnMode::Dma } else { AttnMode::Native };
        let vocab = self.cfg().vocab;
        let mut out = vec![0f32; b * l * vocab];
        for bi in 0..b {
            let mut kv = KvState::new(self.cfg(), l);
            let logits = self
                .model
                .prefill(&tokens[bi * l..(bi + 1) * l], mode, &mut kv)?;
            out[bi * l * vocab..(bi + 1) * l * vocab].copy_from_slice(&logits.data);
        }
        Ok(out)
    }

    fn vocab(&self) -> usize {
        self.cfg().vocab
    }

    fn cache_len(&self) -> usize {
        self.cache_len
    }

    fn decode_buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn kv_dims(&self) -> (usize, usize, usize) {
        let cfg = self.cfg();
        (cfg.n_layers, cfg.n_kv_heads, cfg.d_head)
    }

    fn kv_page_stats(&self) -> KvPageStats {
        self.kv_stats
    }

    fn set_perf(&mut self, threads: usize, decoded_cache_bytes: usize) {
        self.threads = threads.max(1);
        self.model.threads = threads.max(1);
        self.decoded_cache_bytes = decoded_cache_bytes;
    }

    fn set_probe(&mut self, probe: Option<std::sync::Arc<crate::telemetry::LayerProbe>>) {
        self.model.probe = probe;
    }

    fn name(&self) -> &'static str {
        "host-cpu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_then_decode_matches_cpu_model() {
        let mut be = HostBackend::for_tests();
        let toks: Vec<i32> = (0..16).map(|i| ((i * 7) % 60) + 1).collect();
        let out = be.prefill(&toks, false, None).unwrap();
        assert_eq!(out.last_logits.len(), 64);
        assert_eq!(out.kv.pos(), 16);

        // Direct CPU path for comparison.
        let cfg = crate::model::test_config();
        let w = crate::model::random_weights(&cfg, 42);
        let m = CpuModel::new(cfg, w).unwrap();
        let mut kv = KvState::new(&m.cfg, 96);
        let lg = m.prefill(&toks, AttnMode::Native, &mut kv).unwrap();
        for (a, b) in out.last_logits.iter().zip(lg.row(15)) {
            assert!((a - b).abs() < 1e-5);
        }

        // Decode continues correctly through slot round-trips.
        let mut slot = out.kv;
        let logits = be.decode(&[7], &mut [Some(&mut slot)]).unwrap();
        let l2 = m.decode_step(7, &mut kv).unwrap();
        for (a, b) in logits.iter().zip(&l2) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(slot.pos(), 17);
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        // The backend-level chunking contract: advancing a PrefillSeq in
        // small slices ends with the same slot contents and last logits
        // as one full-prompt chunk (f32 path is bit-invariant).
        let toks: Vec<i32> = (0..23).map(|i| ((i * 5) % 60) + 1).collect();

        let mut be1 = HostBackend::for_tests();
        let one = be1.prefill(&toks, false, None).unwrap();

        let mut be2 = HostBackend::for_tests();
        let mut seq = be2.begin_prefill(&toks, false, None, None).unwrap();
        while !seq.is_done() {
            be2.prefill_chunk(&mut seq, 7).unwrap();
        }
        let many = be2.finish_prefill(seq).unwrap();

        assert_eq!(one.last_logits, many.last_logits);
        let (a, b) = (one.kv.as_f32().unwrap(), many.kv.as_f32().unwrap());
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.k, b.k);
        assert_eq!(a.v, b.v);
    }

    #[test]
    fn chunked_quant_prefill_streams_into_pages() {
        use crate::kvquant::{KvFormat, KvPolicy};
        let toks: Vec<i32> = (0..28).map(|i| ((i * 7) % 60) + 1).collect();
        let qcfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 8,
            policies: vec![KvPolicy { sink: 8, diag: 8 }],
        };
        let mut be = HostBackend::for_tests();
        let mut seq = be.begin_prefill(&toks, false, Some(&qcfg), None).unwrap();
        be.prefill_chunk(&mut seq, 16).unwrap();
        assert_eq!(seq.done, 16);
        be.prefill_chunk(&mut seq, 16).unwrap();
        assert!(seq.is_done());
        let out = be.finish_prefill(seq).unwrap();
        let SeqKv::Quant(ref q) = out.kv else { panic!("expected quantized cache") };
        assert_eq!(q.pos, 28);
        // The second chunk attended the first chunk's quantized pages.
        assert!(be.kv_page_stats().total() > 0);

        // Decode proceeds over the streamed cache.
        let mut slot = out.kv;
        let logits = be.decode(&[7], &mut [Some(&mut slot)]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(slot.pos(), 29);
    }

    #[test]
    fn batch_decode_with_padding_slots() {
        let mut be = HostBackend::for_tests();
        let o1 = be.prefill(&[1, 2, 3, 4], false, None).unwrap();
        let mut s1 = o1.kv;
        let logits = be.decode(&[9, 0], &mut [Some(&mut s1), None]).unwrap();
        assert_eq!(logits.len(), 2 * 64);
        assert_eq!(s1.pos(), 5);
    }

    #[test]
    fn quantized_decode_path_runs_and_counts_pages() {
        use crate::kvquant::{KvFormat, KvPolicy};
        let mut be = HostBackend::for_tests();
        let toks: Vec<i32> = (0..28).map(|i| ((i * 7) % 60) + 1).collect();
        let qcfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 8,
            policies: vec![KvPolicy { sink: 8, diag: 8 }],
        };
        let out = be.prefill(&toks, false, Some(&qcfg)).unwrap();
        let mut slot = out.kv;
        assert_eq!(slot.pos(), 28);
        let base_pages = be.kv_page_stats();

        let logits = be.decode(&[7], &mut [Some(&mut slot)]).unwrap();
        assert_eq!(logits.len(), 64);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(slot.pos(), 29);
        // 2 layers x 2 heads x ceil(29/8) pages of K decoded; at 29
        // tokens the sink page and the frontier pages are high, page 1
        // sits in the low body.
        let stats = be.kv_page_stats();
        assert_eq!(stats.total() - base_pages.total(), 2 * 2 * 4);
        assert!(stats.high_pages > 0 && stats.low_pages > 0, "{stats:?}");

        // Quantized decode tracks the f32 path closely enough to stay a
        // plausible distribution (finite, non-degenerate) and similar.
        let mut f32_slot = be.prefill(&toks, false, None).unwrap().kv;
        let f32_logits = be.decode(&[7], &mut [Some(&mut f32_slot)]).unwrap();
        let cos = crate::metrics::cos_sim(&logits, &f32_logits);
        assert!(cos > 0.95, "quantized decode diverged: cos {cos}");
    }

    #[test]
    fn threaded_batch_decode_bit_identical_to_serial() {
        // The per-sequence fan-out (and the model's per-head fan-out
        // underneath) must produce the same logits bytes as threads = 1,
        // for a mixed f32/quantized batch.
        use crate::kvquant::{KvFormat, KvPolicy};
        let qcfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 8,
            policies: vec![KvPolicy { sink: 8, diag: 16 }],
        };
        let run = |threads: usize| {
            let mut be = HostBackend::for_tests()
                .with_perf(threads, crate::kvquant::DECODED_CACHE_BYTES);
            let toks: Vec<i32> = (0..12).map(|i| ((i * 7) % 60) + 1).collect();
            let mut s1 = be.prefill(&toks, false, None).unwrap().kv;
            let mut s2 = be.prefill(&toks, false, Some(&qcfg)).unwrap().kv;
            let mut s3 = be.prefill(&toks[..7], false, Some(&qcfg)).unwrap().kv;
            let mut all = Vec::new();
            for step in 0..3 {
                let logits = be
                    .decode(
                        &[7 + step, 9, 0, 11],
                        &mut [Some(&mut s1), Some(&mut s2), None, Some(&mut s3)],
                    )
                    .unwrap();
                all.push(logits);
            }
            (all, be.kv_page_stats())
        };
        let (l1, st1) = run(1);
        for threads in [2usize, 4] {
            let (l, st) = run(threads);
            assert_eq!(l, l1, "logits diverged at {threads} threads");
            assert_eq!(st, st1, "page stats diverged at {threads} threads");
        }
    }

    #[test]
    fn decode_multi_bit_identical_to_per_token_decode() {
        // The speculative verifier's batched chain walk must reproduce
        // the sequential single-token decode bit for bit — for a mixed
        // f32/quantized batch with uneven chain lengths and a padding
        // slot, at every thread count.
        use crate::kvquant::{KvFormat, KvPolicy};
        let qcfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 8,
            policies: vec![KvPolicy { sink: 8, diag: 16 }],
        };
        let toks: Vec<i32> = (0..12).map(|i| ((i * 7) % 60) + 1).collect();
        let chains: Vec<Vec<i32>> = vec![vec![7, 9, 11], vec![], vec![13, 15], vec![8]];

        // Oracle: per-token decode through the default trait impl's
        // replay (explicit loop here so the oracle cannot share code with
        // the override under test).
        let mut be = HostBackend::for_tests();
        let mut o1 = be.prefill(&toks, false, None).unwrap().kv;
        let mut o2 = be.prefill(&toks, false, Some(&qcfg)).unwrap().kv;
        let mut o3 = be.prefill(&toks[..7], false, Some(&qcfg)).unwrap().kv;
        let mut oracle: Vec<Vec<f32>> = vec![Vec::new(); 4];
        for (i, s) in [(0usize, &mut o1), (2, &mut o2), (3, &mut o3)] {
            for &t in &chains[i] {
                let l = be.decode(&[t], &mut [Some(&mut *s)]).unwrap();
                oracle[i].extend_from_slice(&l);
            }
        }

        for threads in [1usize, 2, 4] {
            let mut be = HostBackend::for_tests()
                .with_perf(threads, crate::kvquant::DECODED_CACHE_BYTES);
            let mut s1 = be.prefill(&toks, false, None).unwrap().kv;
            let mut s2 = be.prefill(&toks, false, Some(&qcfg)).unwrap().kv;
            let mut s3 = be.prefill(&toks[..7], false, Some(&qcfg)).unwrap().kv;
            let rows = be
                .decode_multi(
                    &chains,
                    &mut [Some(&mut s1), None, Some(&mut s2), Some(&mut s3)],
                )
                .unwrap();
            assert_eq!(rows, oracle, "diverged at {threads} threads");
            assert_eq!(rows[1], Vec::<f32>::new());
            assert_eq!(s1.pos(), 15);
            assert_eq!(s2.pos(), 14);
            assert_eq!(s3.pos(), 8);
        }
    }

    #[test]
    fn engine_restart_does_not_leak_pool_workers() {
        // Backends borrow the process-wide worker pool; creating and
        // dropping an engine must not spawn a fresh set of threads per
        // restart. After one warm-up decode (which may lazily grow the
        // pool to the requested width), repeated restarts keep the
        // worker count flat.
        let cycle = || {
            let mut be = HostBackend::for_tests()
                .with_perf(4, crate::kvquant::DECODED_CACHE_BYTES);
            let toks: Vec<i32> = (0..8).map(|i| ((i * 5) % 60) + 1).collect();
            let mut s1 = be.prefill(&toks, false, None).unwrap().kv;
            let mut s2 = be.prefill(&toks, false, None).unwrap().kv;
            be.decode(&[3, 9], &mut [Some(&mut s1), Some(&mut s2)])
                .unwrap();
        };
        cycle();
        let after_first = crate::util::pool::worker_count();
        for _ in 0..32 {
            cycle();
        }
        // Other tests share the process-global pool and may grow it
        // legitimately while this loop runs, so allow slack up to the
        // widest fan-out any test requests — a per-restart leak (3 new
        // workers x 32 cycles) would sail past it.
        let after = crate::util::pool::worker_count();
        assert!(
            after <= after_first.max(63),
            "pool grew across engine restarts: {after_first} -> {after}"
        );
    }

    #[test]
    fn eval_logits_shape() {
        let mut be = HostBackend::for_tests();
        let toks: Vec<i32> = (0..2 * 8).map(|i| (i % 60) as i32 + 1).collect();
        let lg = be.eval_logits(&toks, 2, 8, false).unwrap();
        assert_eq!(lg.len(), 2 * 8 * 64);
    }
}
