//! PJRT-backed [`ModelBackend`]: compiles `artifacts/*.hlo.txt` on the
//! CPU PJRT client and executes them on the request path.
//!
//! Executables are compiled lazily per bucket and cached. Weights are
//! loaded once from `weights.bin` into host literals and passed as
//! leading parameters (the layout contract lives in `model_meta.json`).

use super::{pick_bucket, ModelBackend, PrefillOut, PrefillSeq, PrefillState};
use crate::kvcache::SeqKv;
use crate::config::MetaConfig;
use crate::kvcache::{SlotCache, SlotKv};
use crate::model::weights::Weights;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;

pub struct PjrtBackend {
    pub meta: MetaConfig,
    client: xla::PjRtClient,
    weights: Vec<xla::Literal>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    slots: SlotCache,
    pad_token: i32,
    /// Cumulative executions per artifact (metrics endpoint).
    pub exec_counts: BTreeMap<String, u64>,
}

fn lit_f32(data: &[f32], dims: &[i64]) -> crate::Result<xla::Literal> {
    Ok(xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e}"))?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> crate::Result<xla::Literal> {
    Ok(xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e}"))?)
}

impl PjrtBackend {
    pub fn new(meta: MetaConfig) -> crate::Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        let w = Weights::load(meta.artifact_dir.join("weights.bin"))?;
        w.check_order(&meta.param_order)?;
        let weights = w
            .tensors
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                lit_f32(&t.data, &dims)
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let slots = SlotCache::new(
            meta.model.n_layers,
            meta.model.n_kv_heads,
            meta.cache_len,
            meta.model.d_head,
        );
        let pad_token = meta.tokens.pad;
        Ok(PjrtBackend {
            meta,
            client,
            weights,
            executables: BTreeMap::new(),
            slots,
            pad_token,
            exec_counts: BTreeMap::new(),
        })
    }

    /// Compile an artifact into the cache if not already present.
    fn ensure_compiled(&mut self, name: &str) -> crate::Result<()> {
        if !self.executables.contains_key(name) {
            let path = self.meta.artifact_path(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .with_context(|| format!("loading {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(())
    }

    /// Execute an artifact: weights (if `with_weights`) ++ extra inputs;
    /// returns the decomposed output tuple.
    pub fn run(
        &mut self,
        name: &str,
        with_weights: bool,
        extra: Vec<xla::Literal>,
    ) -> crate::Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exe = self.executables.get(name).unwrap();
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        if with_weights {
            inputs.extend(self.weights.iter());
        }
        inputs.extend(extra.iter());
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e}"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        tuple.to_tuple().map_err(|e| anyhow!("tuple {name}: {e}"))
    }

    /// Smallest exported prefill length >= l.
    fn prefill_bucket(&self, l: usize) -> crate::Result<usize> {
        self.meta
            .prefill_lens
            .iter()
            .copied()
            .find(|&b| b >= l)
            .ok_or_else(|| {
                anyhow!(
                    "prompt length {l} exceeds the largest prefill bucket {:?}",
                    self.meta.prefill_lens
                )
            })
    }
}

impl PjrtBackend {
    /// One monolithic prefill execution (bucketed executables take the
    /// whole prompt; streaming chunks are deferred to this).
    fn prefill_full(&mut self, tokens: &[i32], dma: bool) -> crate::Result<PrefillOut> {
        let l = tokens.len();
        anyhow::ensure!(l > 0, "empty prompt");
        let bucket = self.prefill_bucket(l)?;
        // Right-pad: causal attention keeps logits/caches of real
        // positions independent of trailing padding.
        let mut padded = tokens.to_vec();
        padded.resize(bucket, self.pad_token);
        let mode = if dma { "dma" } else { "native" };
        let name = format!("prefill_{mode}_l{bucket}");
        let toks = lit_i32(&padded, &[bucket as i64])?;
        let outs = self.run(&name, true, vec![toks])?;
        anyhow::ensure!(outs.len() == 3, "prefill returned {} outputs", outs.len());
        let vocab = self.meta.tokens.vocab as usize;
        let logits: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("{e}"))?;
        let kc: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("{e}"))?;
        let vc: Vec<f32> = outs[2].to_vec().map_err(|e| anyhow!("{e}"))?;
        // Slice the real rows out of the padded caches.
        let m = &self.meta.model;
        let (nl, h, dh) = (m.n_layers, m.n_kv_heads, m.d_head);
        let mut kc_real = vec![0f32; nl * h * l * dh];
        let mut vc_real = vec![0f32; nl * h * l * dh];
        for li in 0..nl {
            for hh in 0..h {
                let src = (li * h + hh) * bucket * dh;
                let dst = (li * h + hh) * l * dh;
                kc_real[dst..dst + l * dh].copy_from_slice(&kc[src..src + l * dh]);
                vc_real[dst..dst + l * dh].copy_from_slice(&vc[src..src + l * dh]);
            }
        }
        let slot = self.slots.slot_from_prefill(&kc_real, &vc_real, l)?;
        let last_logits = logits[(l - 1) * vocab..l * vocab].to_vec();
        Ok(PrefillOut { last_logits, kv: SeqKv::F32(slot) })
    }
}

impl ModelBackend for PjrtBackend {
    fn begin_prefill(
        &mut self,
        tokens: &[i32],
        dma: bool,
        quant: Option<&crate::kvquant::KvQuantConfig>,
        seed: Option<crate::kvquant::QuantSlotKv>,
    ) -> crate::Result<PrefillSeq> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(
            quant.is_none() && seed.is_none(),
            "quantized KV cache not supported by the PJRT backend; \
             use kv_format=f32 or the host backend"
        );
        // Bucketed prefill executables take the whole prompt: chunks are
        // only counted and the execution happens once at finish.
        Ok(PrefillSeq {
            tokens: tokens.to_vec(),
            dma,
            done: 0,
            last_logits: Vec::new(),
            state: PrefillState::Deferred,
        })
    }

    fn prefill_chunk(&mut self, seq: &mut PrefillSeq, max_tokens: usize) -> crate::Result<()> {
        anyhow::ensure!(max_tokens > 0, "zero-token prefill chunk");
        // No streaming here: pacing a deferred prefill through multiple
        // scheduler steps would only delay the one monolithic execution,
        // so the first chunk call completes the count.
        seq.done = seq.tokens.len();
        Ok(())
    }

    fn finish_prefill(&mut self, seq: PrefillSeq) -> crate::Result<PrefillOut> {
        anyhow::ensure!(seq.is_done(), "prefill incomplete ({}/{})",
                        seq.done, seq.tokens.len());
        self.prefill_full(&seq.tokens, seq.dma)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        slots: &mut [Option<&mut SeqKv>],
    ) -> crate::Result<Vec<f32>> {
        let n = slots.len();
        anyhow::ensure!(tokens.len() == n, "tokens/slots mismatch");
        let b = pick_bucket(&self.meta.decode_batches, n);
        anyhow::ensure!(b >= n, "decode batch {n} exceeds largest bucket {b}");
        // The bucketed executables take f32 cache literals; a quantized
        // cache cannot be served here without materializing it, which
        // defeats its purpose — reject loudly instead.
        for s in slots.iter().flatten() {
            anyhow::ensure!(
                s.as_f32().is_some(),
                "quantized KV cache not supported by the PJRT backend; \
                 use kv_format=f32 or the host backend"
            );
        }

        // Gather batch caches + positions.
        let mut bk = vec![0f32; self.slots.batch_elems(b)];
        let mut bv = vec![0f32; self.slots.batch_elems(b)];
        {
            let views: Vec<Option<&SlotKv>> = (0..b)
                .map(|i| {
                    slots
                        .get(i)
                        .and_then(|s| s.as_deref())
                        .and_then(SeqKv::as_f32)
                })
                .collect();
            self.slots.gather_batch(&views, &mut bk, &mut bv);
        }
        let mut toks = vec![self.pad_token; b];
        toks[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; b];
        for i in 0..n {
            if let Some(s) = &slots[i] {
                pos[i] = s.pos() as i32;
            }
        }

        let m = &self.meta.model;
        let dims_cache = [
            m.n_layers as i64,
            b as i64,
            m.n_kv_heads as i64,
            self.meta.cache_len as i64,
            m.d_head as i64,
        ];
        let outs = self.run(
            &format!("decode_b{b}"),
            true,
            vec![
                lit_i32(&toks, &[b as i64])?,
                lit_f32(&bk, &dims_cache)?,
                lit_f32(&bv, &dims_cache)?,
                lit_i32(&pos, &[b as i64])?,
            ],
        )?;
        anyhow::ensure!(outs.len() == 3, "decode returned {} outputs", outs.len());
        let logits: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("{e}"))?;
        let nk: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("{e}"))?;
        let nv: Vec<f32> = outs[2].to_vec().map_err(|e| anyhow!("{e}"))?;
        {
            let mut f32_slots: Vec<Option<&mut SlotKv>> = slots
                .iter_mut()
                .map(|s| s.as_deref_mut().and_then(SeqKv::as_f32_mut))
                .collect();
            self.slots.scatter_batch(&nk, &nv, &mut f32_slots);
            for s in f32_slots.into_iter().flatten() {
                s.pos += 1;
            }
        }
        Ok(logits)
    }

    fn eval_logits(
        &mut self,
        tokens: &[i32],
        b: usize,
        l: usize,
        dma: bool,
    ) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == b * l, "tokens shape mismatch");
        anyhow::ensure!(
            self.meta.eval_shapes.contains(&(b, l)),
            "no eval artifact for shape ({b}, {l}); exported: {:?}",
            self.meta.eval_shapes
        );
        let mode = if dma { "dma" } else { "native" };
        let name = format!("eval_{mode}_l{l}_b{b}");
        let toks = lit_i32(tokens, &[b as i64, l as i64])?;
        let outs = self.run(&name, true, vec![toks])?;
        outs[0].to_vec().map_err(|e| anyhow!("{e}"))
    }

    fn vocab(&self) -> usize {
        self.meta.tokens.vocab as usize
    }

    fn cache_len(&self) -> usize {
        self.meta.cache_len
    }

    fn decode_buckets(&self) -> Vec<usize> {
        self.meta.decode_batches.clone()
    }

    fn kv_dims(&self) -> (usize, usize, usize) {
        let m = &self.meta.model;
        (m.n_layers, m.n_kv_heads, m.d_head)
    }

    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }
}
