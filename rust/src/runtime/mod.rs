//! Runtime: loads AOT artifacts (`*.hlo.txt`) and executes them via the
//! PJRT C API (`xla` crate), plus a pure-Rust host fallback behind the
//! same trait so the serving stack tests without artifacts.
//!
//! HLO **text** is the interchange format — jax >= 0.5 serialized protos
//! use 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod host;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::kvcache::{SeqKv, SlotKv};

/// Result of prefilling one sequence.
pub struct PrefillOut {
    /// Logits of the last *real* (unpadded) position, length = vocab.
    pub last_logits: Vec<f32>,
    /// Per-sequence KV cache, padded to the engine cache length. Always
    /// f32 — the engine quantizes it into a paged store right after
    /// prefill when `kv_format` asks for one.
    pub slot: SlotKv,
}

/// The serving engine's view of a model executor. One instance services
/// one worker thread (PJRT handles are not shared across threads).
pub trait ModelBackend {
    /// Prefill a prompt; `dma` selects the mixed-precision attention
    /// artifacts (vs native/full-precision).
    fn prefill(&mut self, tokens: &[i32], dma: bool) -> crate::Result<PrefillOut>;

    /// One decode step over a batch of sequence caches. `tokens[i]` is
    /// fed to `slots[i]`; `None` slots are padding. Returns `[B * vocab]`
    /// logits (rows of padding slots are garbage). Backends dispatch on
    /// the [`SeqKv`] variant; a backend without a quantized decode path
    /// must error on [`SeqKv::Quant`] rather than silently dequantize.
    fn decode(
        &mut self,
        tokens: &[i32],
        slots: &mut [Option<&mut SeqKv>],
    ) -> crate::Result<Vec<f32>>;

    /// Batched full-sequence logits for the eval harness:
    /// tokens [B, L] row-major -> logits [B, L, vocab].
    fn eval_logits(&mut self, tokens: &[i32], b: usize, l: usize, dma: bool)
        -> crate::Result<Vec<f32>>;

    /// Vocabulary size (logit row width).
    fn vocab(&self) -> usize;

    /// Engine cache capacity per sequence.
    fn cache_len(&self) -> usize;

    /// Decode batch buckets available, ascending.
    fn decode_buckets(&self) -> Vec<usize>;

    /// Model geometry the engine needs for format-aware KV accounting:
    /// `(n_layers, n_kv_heads, d_head)`.
    fn kv_dims(&self) -> (usize, usize, usize);

    /// Cumulative per-precision page-decode counters (quantized caches
    /// only; backends without a paged path report zeros).
    fn kv_page_stats(&self) -> crate::metrics::KvPageStats {
        crate::metrics::KvPageStats::default()
    }

    /// Human-readable backend name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Pick the smallest bucket >= `n`, or the largest bucket if none fits
/// (the caller then splits the batch).
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().expect("no buckets")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = vec![1, 2, 4];
        assert_eq!(pick_bucket(&buckets, 1), 1);
        assert_eq!(pick_bucket(&buckets, 2), 2);
        assert_eq!(pick_bucket(&buckets, 3), 4);
        assert_eq!(pick_bucket(&buckets, 4), 4);
        assert_eq!(pick_bucket(&buckets, 9), 4); // caller splits
    }
}
