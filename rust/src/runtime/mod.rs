//! Runtime: loads AOT artifacts (`*.hlo.txt`) and executes them via the
//! PJRT C API (`xla` crate), plus a pure-Rust host fallback behind the
//! same trait so the serving stack tests without artifacts.
//!
//! HLO **text** is the interchange format — jax >= 0.5 serialized protos
//! use 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod host;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::kvcache::SeqKv;
use crate::kvquant::{KvQuantConfig, QuantSlotKv};

/// Result of finishing one sequence's prefill.
pub struct PrefillOut {
    /// Logits of the last *real* (unpadded) position, length = vocab.
    pub last_logits: Vec<f32>,
    /// The sequence's decode cache: a padded f32 batch slot, or the
    /// quantized paged store the prefill chunks streamed into directly
    /// (no f32 staging slot exists for quantized formats).
    pub kv: SeqKv,
}

/// Streaming prefill in flight for one sequence. The engine owns this
/// between scheduler steps, advancing it one `--prefill-chunk` slice at a
/// time so prefill interleaves with decode instead of stalling it.
pub struct PrefillSeq {
    /// The full prompt.
    pub tokens: Vec<i32>,
    /// Use the DMA (mixed-precision) attention path.
    pub dma: bool,
    /// Prompt tokens already run through the model (includes any shared
    /// prefix imported from the radix cache — those were never run here).
    pub done: usize,
    /// Logits of the last processed position. Sharing is capped strictly
    /// inside the prompt, so at least one chunk always runs and this is
    /// populated by the time the prefill finishes.
    pub last_logits: Vec<f32>,
    pub state: PrefillState,
}

/// Backend-side working state of a streaming prefill.
pub enum PrefillState {
    /// Exact f32 working cache, prompt-length (host backend, f32 serving
    /// format). Converted to a padded batch slot at finish; the old
    /// cache-length staging slot is gone.
    F32(crate::model::KvState),
    /// Quantized paged stores; chunks quantize-on-append and attend the
    /// quantized prefix (host backend, quantized formats). May start
    /// seeded with shared pages from the radix prefix cache.
    Quant(QuantSlotKv),
    /// The backend cannot stream (bucketed PJRT prefill executables take
    /// the whole prompt): chunks are only counted, and `finish_prefill`
    /// runs one monolithic execution.
    Deferred,
}

impl PrefillSeq {
    pub fn remaining(&self) -> usize {
        self.tokens.len() - self.done
    }

    pub fn is_done(&self) -> bool {
        self.done >= self.tokens.len()
    }

    /// Resident bytes of the in-flight working cache (the engine folds
    /// this into its peak-bytes accounting — chunked prefill is exactly
    /// when a sequence's cache grows).
    pub fn resident_bytes(&self) -> usize {
        match &self.state {
            PrefillState::F32(kv) => kv
                .k
                .iter()
                .flatten()
                .chain(kv.v.iter().flatten())
                .map(|t| t.data.len() * 4)
                .sum(),
            PrefillState::Quant(q) => q.quantized_bytes() + q.decoded_bytes(),
            PrefillState::Deferred => 0,
        }
    }
}

/// The serving engine's view of a model executor. One instance services
/// one worker thread (PJRT handles are not shared across threads).
pub trait ModelBackend {
    /// Begin a streaming prefill. `quant` selects quantize-on-append into
    /// paged stores; `seed` imports a radix-cache prefix hit (a slot
    /// pre-populated with `seed.pos` tokens of shared pages — quantized
    /// formats only).
    fn begin_prefill(
        &mut self,
        tokens: &[i32],
        dma: bool,
        quant: Option<&KvQuantConfig>,
        seed: Option<QuantSlotKv>,
    ) -> crate::Result<PrefillSeq>;

    /// Advance a streaming prefill by up to `max_tokens` prompt tokens.
    fn prefill_chunk(&mut self, seq: &mut PrefillSeq, max_tokens: usize)
        -> crate::Result<()>;

    /// Complete a finished (`seq.is_done()`) prefill: last-position
    /// logits plus the sequence's decode cache.
    fn finish_prefill(&mut self, seq: PrefillSeq) -> crate::Result<PrefillOut>;

    /// Convenience: run a whole prompt as one chunk (tests, eval,
    /// latency-insensitive callers).
    fn prefill(
        &mut self,
        tokens: &[i32],
        dma: bool,
        quant: Option<&KvQuantConfig>,
    ) -> crate::Result<PrefillOut> {
        let mut seq = self.begin_prefill(tokens, dma, quant, None)?;
        self.prefill_chunk(&mut seq, tokens.len())?;
        self.finish_prefill(seq)
    }

    /// One decode step over a batch of sequence caches. `tokens[i]` is
    /// fed to `slots[i]`; `None` slots are padding. Returns `[B * vocab]`
    /// logits (rows of padding slots are garbage). Backends dispatch on
    /// the [`SeqKv`] variant; a backend without a quantized decode path
    /// must error on [`SeqKv::Quant`] rather than silently dequantize.
    fn decode(
        &mut self,
        tokens: &[i32],
        slots: &mut [Option<&mut SeqKv>],
    ) -> crate::Result<Vec<f32>>;

    /// Multi-token decode for speculative verification ([`crate::spec`]):
    /// feed `chains[i]` (the sequence's next token followed by its draft
    /// tokens) into `slots[i]` one token at a time, returning each slot's
    /// flat `[chains[i].len() * vocab]` logits — row `j` is the logits
    /// after appending `chains[i][..=j]`. `None` slots get an empty row
    /// vector. The default implementation replays the single-token
    /// [`Self::decode`] per token, so it is bit-identical to sequential
    /// decode by construction; backends override it to batch the chain
    /// walk without changing the bits.
    fn decode_multi(
        &mut self,
        chains: &[Vec<i32>],
        slots: &mut [Option<&mut SeqKv>],
    ) -> crate::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(chains.len() == slots.len(), "chains/slots length mismatch");
        let vocab = self.vocab();
        let mut out = vec![Vec::new(); chains.len()];
        for (i, chain) in chains.iter().enumerate() {
            let Some(s) = slots[i].as_mut() else { continue };
            let rows = &mut out[i];
            rows.reserve(chain.len() * vocab);
            for &t in chain {
                let logits = self.decode(&[t], &mut [Some(&mut **s)])?;
                rows.extend_from_slice(&logits[..vocab]);
            }
        }
        Ok(out)
    }

    /// Batched full-sequence logits for the eval harness:
    /// tokens [B, L] row-major -> logits [B, L, vocab].
    fn eval_logits(&mut self, tokens: &[i32], b: usize, l: usize, dma: bool)
        -> crate::Result<Vec<f32>>;

    /// Vocabulary size (logit row width).
    fn vocab(&self) -> usize;

    /// Engine cache capacity per sequence.
    fn cache_len(&self) -> usize;

    /// Decode batch buckets available, ascending.
    fn decode_buckets(&self) -> Vec<usize>;

    /// Model geometry the engine needs for format-aware KV accounting:
    /// `(n_layers, n_kv_heads, d_head)`.
    fn kv_dims(&self) -> (usize, usize, usize);

    /// Cumulative per-precision page-decode counters (quantized caches
    /// only; backends without a paged path report zeros).
    fn kv_page_stats(&self) -> crate::metrics::KvPageStats {
        crate::metrics::KvPageStats::default()
    }

    /// Apply the engine's performance knobs: `threads` worker threads for
    /// intra-step fan-out (per-sequence decode, per-kv-head attention)
    /// and the per-slot decoded-page cache byte budget. Backends without
    /// those mechanisms (PJRT executables) ignore this.
    fn set_perf(&mut self, _threads: usize, _decoded_cache_bytes: usize) {}

    /// Attach the sampled per-layer timing probe
    /// ([`crate::telemetry::LayerProbe`], `--metrics-sample-n`), or
    /// detach with `None`. Backends without layer-level instrumentation
    /// ignore it.
    fn set_probe(&mut self, _probe: Option<std::sync::Arc<crate::telemetry::LayerProbe>>) {}

    /// Human-readable backend name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Pick the smallest bucket >= `n`, or the largest bucket if none fits
/// (the caller then splits the batch).
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().expect("no buckets")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = vec![1, 2, 4];
        assert_eq!(pick_bucket(&buckets, 1), 1);
        assert_eq!(pick_bucket(&buckets, 2), 2);
        assert_eq!(pick_bucket(&buckets, 3), 4);
        assert_eq!(pick_bucket(&buckets, 4), 4);
        assert_eq!(pick_bucket(&buckets, 9), 4); // caller splits
    }
}
