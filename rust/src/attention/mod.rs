//! Attention implementations on the CPU side.
//!
//! These mirror the Pallas kernels numerically and serve three roles:
//! (1) oracles for integration tests against the PJRT executables,
//! (2) the measurable substrate for the paper's latency/similarity
//! tables on this testbed, and (3) the host fallback when artifacts are
//! absent.
//!
//! * [`reference`]      — exact softmax attention (naive, materializes S)
//! * [`online_softmax`] — streaming row accumulator (Sec. 3.2)
//! * [`flash`]          — tiled exact attention (FlashAttention loop)
//! * [`dma`]            — Diagonal-Tiled Mixed-Precision (Algorithm 1)
//! * [`paged`]          — DMA decode over a quantized paged KV cache
//!                        ([`crate::kvquant`])

pub mod dma;
pub mod flash;
pub mod online_softmax;
pub mod paged;
pub mod reference;

/// Tiling/window configuration shared by the tiled kernels.
#[derive(Clone, Copy, Debug)]
pub struct TileConfig {
    /// Query tile rows (B_M).
    pub bm: usize,
    /// Key/value tile rows (B_N).
    pub bn: usize,
    /// Diagonal window size T in tokens (0 = everything low precision).
    pub diag: usize,
    /// Attention-sink window in tokens from position 0.
    pub sink: usize,
    pub causal: bool,
}

impl Default for TileConfig {
    fn default() -> Self {
        // The paper's default configuration: 128/128 diagonal/sink.
        TileConfig { bm: 64, bn: 64, diag: 128, sink: 128, causal: true }
    }
}

impl TileConfig {
    pub fn with_diag_sink(diag: usize, sink: usize) -> Self {
        TileConfig { diag, sink, ..Default::default() }
    }

    /// Fraction of the (causally valid) attention area computed in high
    /// precision.
    pub fn high_fraction(&self, lq: usize, lk: usize) -> f64 {
        self.high_area(lq, lk).0
    }

    /// The paper's "Bithigh%" column (Table 5) normalizes by the FULL
    /// L x L matrix, not the causally valid half (the reported 1.15% for
    /// diag=128 equals diag/L at L ~= 11.1k). This variant matches that
    /// convention.
    pub fn high_fraction_full(&self, lq: usize, lk: usize) -> f64 {
        self.high_area(lq, lk).1
    }

    /// (valid-normalized, full-normalized) high-precision area fractions.
    fn high_area(&self, lq: usize, lk: usize) -> (f64, f64) {
        let off = lk as i64 - lq as i64;
        let mut high = 0u64;
        let mut valid = 0u64;
        for qi in 0..lq {
            let ti = qi / self.bm;
            let frontier = (ti * self.bm + self.bm - 1) as i64 + off;
            for kj in 0..lk {
                let causal_ok = !self.causal || (kj as i64) <= qi as i64 + off;
                if !causal_ok {
                    continue;
                }
                valid += 1;
                let tj = kj / self.bn;
                let t0 = (tj * self.bn) as i64;
                let t1 = (tj * self.bn + self.bn - 1) as i64;
                let in_diag = self.diag > 0
                    && t1 >= frontier - (self.diag as i64 - 1)
                    && t0 <= frontier;
                let in_sink = self.sink > 0 && (tj * self.bn) < self.sink;
                if in_diag || in_sink {
                    high += 1;
                }
            }
        }
        let full = (lq as u64) * (lk as u64);
        (
            if valid == 0 { 0.0 } else { high as f64 / valid as f64 },
            if full == 0 { 0.0 } else { high as f64 / full as f64 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_fraction_monotone_in_diag() {
        let fr: Vec<f64> = [0, 64, 128, 256, 512]
            .iter()
            .map(|&d| TileConfig::with_diag_sink(d, 0).high_fraction(512, 512))
            .collect();
        for w in fr.windows(2) {
            assert!(w[0] <= w[1], "{fr:?}");
        }
    }

    #[test]
    fn high_fraction_table5_values() {
        // Paper Table 5 reports Bithigh% over the FULL matrix at
        // L ~= 11.1k (1.15% for diag=128 = 128/L): reproduce the band
        // with full-matrix normalization at L = 11136 (multiple of 64).
        let l = 11136;
        let f = TileConfig::with_diag_sink(128, 0).high_fraction_full(l, l);
        assert!((f - 0.0115).abs() < 0.006, "diag128: {f}");
        let f = TileConfig::with_diag_sink(128, 128).high_fraction_full(l, l);
        assert!((f - 0.023).abs() < 0.008, "128/128: {f}");
        let f = TileConfig::with_diag_sink(512, 512).high_fraction_full(l, l);
        assert!((f - 0.0922).abs() < 0.02, "512/512: {f}");
        let f = TileConfig::with_diag_sink(2048, 2048).high_fraction_full(l, l);
        assert!((f - 0.3687).abs() < 0.08, "2048/2048: {f}"); // triangle-truncation convention differs at large windows
    }

    #[test]
    fn zero_windows_zero_fraction() {
        let f = TileConfig::with_diag_sink(0, 0).high_fraction(256, 256);
        assert_eq!(f, 0.0);
    }
}
