//! Tiled exact attention (FlashAttention-style loop) on the CPU — the
//! "Native" baseline, structurally identical to the DMA kernel so
//! comparisons isolate the mixed-precision logic.

use super::online_softmax::OnlineSoftmax;
use super::TileConfig;
use crate::tensor::Tensor;

/// Tiled exact attention. q:[Lq,D], k,v:[Lk,D] -> [Lq,D].
pub fn flash_attention(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &TileConfig) -> Tensor {
    let (lq, d) = (q.rows(), q.cols());
    let lk = k.rows();
    assert_eq!(lq % cfg.bm, 0, "Lq={lq} % bm={} != 0", cfg.bm);
    assert_eq!(lk % cfg.bn, 0, "Lk={lk} % bn={} != 0", cfg.bn);
    let off = lk as i64 - lq as i64;
    let scale = 1.0 / (d as f32).sqrt();
    let nk = lk / cfg.bn;

    let mut out = Tensor::zeros(vec![lq, d]);
    let mut s_tile = vec![0f32; cfg.bm * cfg.bn];
    let mut scratch = vec![0f32; cfg.bm * cfg.bn];

    for i in 0..lq / cfg.bm {
        let frontier = (i * cfg.bm + cfg.bm - 1) as i64 + off;
        let j_end = if cfg.causal {
            (((frontier / cfg.bn as i64) + 1).max(0) as usize).min(nk)
        } else {
            nk
        };
        let mut os = OnlineSoftmax::new(cfg.bm, d, false);
        for j in 0..j_end {
            // s = (Q_i / sqrt(d)) K_j^T with causal mask.
            for r in 0..cfg.bm {
                let qrow = q.row(i * cfg.bm + r);
                let limit = (i * cfg.bm + r) as i64 + off;
                for c in 0..cfg.bn {
                    let col = j * cfg.bn + c;
                    if cfg.causal && col as i64 > limit {
                        s_tile[r * cfg.bn + c] = f32::NEG_INFINITY;
                    } else {
                        let krow = k.row(col);
                        let mut acc = 0f32;
                        for (a, b) in qrow.iter().zip(krow) {
                            acc += a * b;
                        }
                        s_tile[r * cfg.bn + c] = acc * scale;
                    }
                }
            }
            let v_tile = v.slice_rows(j * cfg.bn, (j + 1) * cfg.bn);
            os.update(&s_tile, &v_tile.data, cfg.bn, &mut scratch);
        }
        let mut tile_out = vec![0f32; cfg.bm * d];
        os.finalize(&mut tile_out);
        for r in 0..cfg.bm {
            out.row_mut(i * cfg.bm + r).copy_from_slice(&tile_out[r * d..(r + 1) * d]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::reference;
    use crate::tensor::randn;

    fn close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.shape, b.shape);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matches_reference_causal() {
        let q = randn(vec![128, 32], 1);
        let k = randn(vec![128, 32], 2);
        let v = randn(vec![128, 32], 3);
        let cfg = TileConfig { bm: 32, bn: 32, diag: 0, sink: 0, causal: true };
        close(&flash_attention(&q, &k, &v, &cfg),
              &reference::attention(&q, &k, &v, true), 1e-4);
    }

    #[test]
    fn matches_reference_noncausal() {
        let q = randn(vec![64, 16], 4);
        let k = randn(vec![64, 16], 5);
        let v = randn(vec![64, 16], 6);
        let cfg = TileConfig { bm: 16, bn: 32, diag: 0, sink: 0, causal: false };
        close(&flash_attention(&q, &k, &v, &cfg),
              &reference::attention(&q, &k, &v, false), 1e-4);
    }

    #[test]
    fn rectangular_qk() {
        let q = randn(vec![32, 16], 7);
        let k = randn(vec![96, 16], 8);
        let v = randn(vec![96, 16], 9);
        let cfg = TileConfig { bm: 16, bn: 16, diag: 0, sink: 0, causal: true };
        close(&flash_attention(&q, &k, &v, &cfg),
              &reference::attention(&q, &k, &v, true), 1e-4);
    }

    #[test]
    fn tile_size_invariant() {
        let q = randn(vec![64, 32], 10);
        let k = randn(vec![64, 32], 11);
        let v = randn(vec![64, 32], 12);
        let a = flash_attention(&q, &k, &v,
            &TileConfig { bm: 16, bn: 16, diag: 0, sink: 0, causal: true });
        let b = flash_attention(&q, &k, &v,
            &TileConfig { bm: 64, bn: 32, diag: 0, sink: 0, causal: true });
        close(&a, &b, 1e-4);
    }
}
