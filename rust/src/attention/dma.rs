//! Diagonal-Tiled Mixed-Precision Attention (paper Algorithm 1) in Rust.
//!
//! Mirrors `python/compile/kernels/dma_attention.py`: consumes the
//! bit-level outputs of the fused dual quantizer, decodes tiles just
//! before each matmul, and stitches three phases with base-2
//! OnlineSoftmax:
//!
//!   Phase 0 — attention-sink tiles (first `sink` keys), MXFP8 high;
//!   Phase 1 — everything before the diagonal window, NVFP4 low;
//!   Phase 2 — the `diag`-token window at the causal frontier, MXFP8
//!             high + causal mask.
//!
//! Also provides the fixed-format baselines of Tables 2 and 4
//! ([`fixed_format_attention`]).

use super::online_softmax::OnlineSoftmax;
use super::TileConfig;
use crate::mxfp::block::{fake_quant, fake_quant_scaled, Format, Granularity};
use crate::mxfp::fused::DualQuantized;
use crate::tensor::Tensor;

/// Dot product blocked into four independent accumulator chains so the
/// adds pipeline instead of serializing on one dependency chain (f32
/// reassociation is deterministic — the same blocking always produces
/// the same bits, and every kernel sharing this helper stays mutually
/// bit-exact). Delegates to [`crate::simd`], which vectorizes the same
/// chain structure when the `simd` feature is on.
#[inline]
pub(crate) fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    crate::simd::dot_blocked(a, b)
}

/// Compute one `[rows, cols]` logit tile over decoded operands:
/// `s[r, c] = q_dec[r] . k_tile[c]`, with causal masking against absolute
/// positions (`q_pos0 + r` is the position of query row `r`, `col0 + c`
/// the position of key column `c`). Shared by the contiguous DMA loop and
/// the paged decode path ([`super::paged`]) so both produce bit-identical
/// floating-point operation sequences.
///
/// Hot-path shape: the causal bound is hoisted to a per-row column limit
/// (masked columns are bulk-filled, never branched per element) and the
/// `d`-dot is unrolled into fixed-width accumulator blocks
/// ([`dot_blocked`]).
pub(crate) fn score_tile(
    q_dec: &[f32],
    rows: usize,
    d: usize,
    k_tile: &[f32],
    cols: usize,
    q_pos0: i64,
    col0: usize,
    causal: bool,
    s_tile: &mut [f32],
) {
    for r in 0..rows {
        let qrow = &q_dec[r * d..(r + 1) * d];
        let srow = &mut s_tile[r * cols..(r + 1) * cols];
        // Per-row causal column limit: columns [0, c_end) are live, the
        // rest are masked in one pass — no per-element branch.
        let c_end = if causal {
            let limit = q_pos0 + r as i64; // last visible absolute position
            ((limit + 1 - col0 as i64).max(0) as usize).min(cols)
        } else {
            cols
        };
        for (c, sv) in srow[..c_end].iter_mut().enumerate() {
            // Base-2 logits: softmax scale folded into Q.
            *sv = dot_blocked(qrow, &k_tile[c * d..(c + 1) * d]);
        }
        srow[c_end..].fill(f32::NEG_INFINITY);
    }
}

/// DMA attention over pre-quantized Q/K (`is_query=true/false` outputs of
/// [`crate::mxfp::fused::dual_quant`]) and full-precision V.
pub fn dma_attention_quantized(
    qq: &DualQuantized,
    kq: &DualQuantized,
    v: &Tensor,
    cfg: &TileConfig,
) -> Tensor {
    let (lq, d) = (qq.rows, qq.d);
    let lk = kq.rows;
    assert_eq!(kq.d, d);
    assert_eq!(v.rows(), lk);
    assert_eq!(lq % cfg.bm, 0, "Lq={lq} % bm={}", cfg.bm);
    assert_eq!(lk % cfg.bn, 0, "Lk={lk} % bn={}", cfg.bn);
    let off = lk as i64 - lq as i64;
    let nk = lk / cfg.bn;
    let n_sink = cfg.sink.div_ceil(cfg.bn);

    let mut out = Tensor::zeros(vec![lq, d]);
    // Hot-loop scratch, allocated once.
    let mut q_low = vec![0f32; cfg.bm * d];
    let mut q_high = vec![0f32; cfg.bm * d];
    let mut k_tile = vec![0f32; cfg.bn * d];
    let mut s_tile = vec![0f32; cfg.bm * cfg.bn];
    let mut scratch = vec![0f32; cfg.bm * cfg.bn];

    for i in 0..lq / cfg.bm {
        qq.decode_low_rows(i * cfg.bm, (i + 1) * cfg.bm, &mut q_low);
        qq.decode_high_rows(i * cfg.bm, (i + 1) * cfg.bm, &mut q_high);

        let frontier = (i * cfg.bm + cfg.bm - 1) as i64 + off;
        let j_end = if cfg.causal {
            (((frontier / cfg.bn as i64) + 1).max(0) as usize).min(nk)
        } else {
            nk
        };
        // Phase boundaries (tile indices). Causal: window ends at the
        // frontier; non-causal: straddles it by diag/2 each side.
        let n_sink_eff = n_sink.min(j_end);
        let (j_hi_start, j_hi_end) = if cfg.diag == 0 {
            (j_end, j_end)
        } else if cfg.causal {
            let ws = frontier - cfg.diag as i64 + 1;
            let hs = ws
                .div_euclid(cfg.bn as i64)
                .max(n_sink_eff as i64)
                .min(j_end as i64) as usize;
            (hs, j_end)
        } else {
            let half = (cfg.diag / 2) as i64;
            let hs = (frontier - half)
                .div_euclid(cfg.bn as i64)
                .max(n_sink_eff as i64)
                .min(j_end as i64) as usize;
            let he = ((frontier + half).div_euclid(cfg.bn as i64) + 1)
                .max(hs as i64)
                .min(j_end as i64) as usize;
            (hs, he)
        };
        let n_sink_eff = n_sink_eff.min(j_hi_start);

        let mut os = OnlineSoftmax::new(cfg.bm, d, true);
        let mut do_tile = |j: usize, high: bool, os: &mut OnlineSoftmax| {
            if high {
                kq.decode_high_rows(j * cfg.bn, (j + 1) * cfg.bn, &mut k_tile);
            } else {
                kq.decode_low_rows(j * cfg.bn, (j + 1) * cfg.bn, &mut k_tile);
            }
            let q_dec = if high { &q_high } else { &q_low };
            score_tile(
                q_dec, cfg.bm, d, &k_tile, cfg.bn,
                (i * cfg.bm) as i64 + off, j * cfg.bn, cfg.causal,
                &mut s_tile,
            );
            let v_tile = v.slice_rows(j * cfg.bn, (j + 1) * cfg.bn);
            os.update(&s_tile, &v_tile.data, cfg.bn, &mut scratch);
        };

        // Phase 0: sink (high precision).
        for j in 0..n_sink_eff {
            do_tile(j, true, &mut os);
        }
        // Phase 1: low-precision body.
        for j in n_sink_eff..j_hi_start {
            do_tile(j, false, &mut os);
        }
        // Phase 2: diagonal window (high precision).
        for j in j_hi_start..j_hi_end {
            do_tile(j, true, &mut os);
        }
        // Non-causal Phase 1b: low tiles after the window.
        for j in j_hi_end..j_end {
            do_tile(j, false, &mut os);
        }

        let mut tile_out = vec![0f32; cfg.bm * d];
        os.finalize(&mut tile_out);
        for r in 0..cfg.bm {
            out.row_mut(i * cfg.bm + r)
                .copy_from_slice(&tile_out[r * d..(r + 1) * d]);
        }
    }
    out
}

/// Full DMA pipeline on float inputs: fused dual quantization of Q and K,
/// then the mixed-precision attention loop.
pub fn dma_attention(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &TileConfig) -> Tensor {
    let qq = crate::mxfp::fused::dual_quant(
        &q.data, q.rows(), q.cols(), true, Granularity::PerToken);
    let kq = crate::mxfp::fused::dual_quant(
        &k.data, k.rows(), k.cols(), false, Granularity::PerToken);
    dma_attention_quantized(&qq, &kq, v, cfg)
}

/// Fixed-format quantized attention — the MXFP4 / NVFP4 / MXFP8 baselines
/// of Tables 2 and 4. Q and K are fake-quantized in `format` (optionally
/// with a tokenwise outer scale), V stays full precision.
pub fn fixed_format_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    format: Format,
    tokenwise: bool,
    cfg: &TileConfig,
) -> Tensor {
    let quant = |t: &Tensor| -> Tensor {
        let data = if tokenwise {
            fake_quant_scaled(&t.data, t.rows(), t.cols(), format, Granularity::PerToken)
        } else {
            fake_quant(&t.data, t.rows(), t.cols(), format)
        };
        Tensor::new(t.shape.clone(), data)
    };
    let qf = quant(q);
    let kf = quant(k);
    super::flash::flash_attention(&qf, &kf, v, cfg)
}

/// DMA post-softmax attention matrix (tile-level precision mixture) for
/// the error studies (Tables 2/5/8): P computed from the dual-quantized
/// copies with the diagonal/sink window selecting MXFP8 tiles.
pub fn dma_scores(q: &Tensor, k: &Tensor, cfg: &TileConfig,
                  granularity: Granularity) -> Tensor {
    let (lq, d) = (q.rows(), q.cols());
    let lk = k.rows();
    let qq = crate::mxfp::fused::dual_quant(&q.data, lq, d, true, granularity);
    let kq = crate::mxfp::fused::dual_quant(&k.data, lk, d, false, granularity);
    let mut ql = vec![0f32; lq * d];
    let mut qh = vec![0f32; lq * d];
    let mut kl = vec![0f32; lk * d];
    let mut kh = vec![0f32; lk * d];
    qq.dequant_low(&mut ql);
    qq.dequant_high(&mut qh);
    kq.dequant_low(&mut kl);
    kq.dequant_high(&mut kh);
    let s_low = Tensor::new(vec![lq, d], ql).matmul_t(&Tensor::new(vec![lk, d], kl));
    let s_high = Tensor::new(vec![lq, d], qh).matmul_t(&Tensor::new(vec![lk, d], kh));
    let off = lk as i64 - lq as i64;
    let mut s = Tensor::zeros(vec![lq, lk]);
    for qi in 0..lq {
        let ti = qi / cfg.bm;
        let frontier = (ti * cfg.bm + cfg.bm - 1) as i64 + off;
        for kj in 0..lk {
            let tj = kj / cfg.bn;
            let t0 = (tj * cfg.bn) as i64;
            let t1 = (tj * cfg.bn + cfg.bn - 1) as i64;
            let in_diag = cfg.diag > 0
                && t1 >= frontier - (cfg.diag as i64 - 1)
                && t0 <= frontier;
            let in_sink = cfg.sink > 0 && (tj * cfg.bn) < cfg.sink;
            let v = if in_diag || in_sink {
                s_high.at(qi, kj)
            } else {
                s_low.at(qi, kj)
            };
            s.set(qi, kj, v);
        }
    }
    if cfg.causal {
        super::reference::apply_causal_mask(&mut s, lq, lk);
    }
    // Base-2 logits (softmax scale folded into Q by the quantizer).
    s.scale(std::f32::consts::LN_2).softmax_rows()
}

/// Quantized attention-score matrix for the error studies (Table 2,
/// Fig. 1): P computed from fake-quantized Q/K.
pub fn quantized_scores(
    q: &Tensor,
    k: &Tensor,
    format: Format,
    tokenwise: bool,
    causal: bool,
) -> Tensor {
    let quant = |t: &Tensor| -> Tensor {
        let data = if tokenwise {
            fake_quant_scaled(&t.data, t.rows(), t.cols(), format, Granularity::PerToken)
        } else {
            fake_quant(&t.data, t.rows(), t.cols(), format)
        };
        Tensor::new(t.shape.clone(), data)
    };
    super::reference::attention_scores(&quant(q), &quant(k), causal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::reference;
    use crate::metrics;
    use crate::tensor::randn;
    use crate::util::rng::{channelwise_qk, Rng};

    fn qkv(l: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        (randn(vec![l, d], seed), randn(vec![l, d], seed + 1), randn(vec![l, d], seed + 2))
    }

    #[test]
    fn score_tile_matches_naive_reference() {
        // The blocked, hoisted-causal kernel vs a per-element oracle:
        // masked cells are exactly -inf, live cells match an f64 dot to
        // rounding noise. Covers fully-masked rows, partial limits,
        // widths not a multiple of the accumulator block, non-causal.
        let mut rng = Rng::new(77);
        for &(rows, d, cols, col0, q_pos0, causal) in &[
            (4usize, 32usize, 8usize, 0usize, 0i64, true),
            (1, 64, 16, 16, 30, true),
            (3, 48, 8, 240, 2, true), // every column masked
            (2, 33, 5, 0, 100, true), // d % 4 != 0 tail
            (2, 40, 7, 3, 0, false),
        ] {
            let q: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
            let k: Vec<f32> = (0..cols * d).map(|_| rng.normal() as f32).collect();
            let mut fast = vec![0f32; rows * cols];
            score_tile(&q, rows, d, &k, cols, q_pos0, col0, causal, &mut fast);
            for r in 0..rows {
                let limit = q_pos0 + r as i64;
                for c in 0..cols {
                    let got = fast[r * cols + c];
                    if causal && (col0 + c) as i64 > limit {
                        assert_eq!(got, f32::NEG_INFINITY, "r{r} c{c} not masked");
                    } else {
                        let mut acc = 0f64;
                        for i in 0..d {
                            acc += q[r * d + i] as f64 * k[c * d + i] as f64;
                        }
                        let expect = acc as f32;
                        assert!(
                            (got - expect).abs() <= 1e-4 * (1.0 + expect.abs()),
                            "r{r} c{c}: {got} vs {expect}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn close_to_exact_attention() {
        let (q, k, v) = qkv(256, 64, 1);
        let cfg = TileConfig { bm: 64, bn: 64, diag: 128, sink: 64, causal: true };
        let o = dma_attention(&q, &k, &v, &cfg);
        let o_ref = reference::attention(&q, &k, &v, true);
        let cos = metrics::cos_sim(&o.data, &o_ref.data);
        assert!(cos > 0.998, "cos {cos}");
    }

    #[test]
    fn full_high_window_equals_mxfp8_quality() {
        let (q, k, v) = qkv(128, 64, 4);
        let cfg = TileConfig { bm: 64, bn: 64, diag: 4096, sink: 0, causal: true };
        let o = dma_attention(&q, &k, &v, &cfg);
        let o_ref = reference::attention(&q, &k, &v, true);
        assert!(metrics::cos_sim(&o.data, &o_ref.data) > 0.999);
    }

    #[test]
    fn diag_window_recovers_accuracy() {
        // The paper's core claim on channel-structured data.
        let mut rng = Rng::new(9);
        let d = 64;
        let l = 256;
        let q = Tensor::new(vec![l, d], channelwise_qk(&mut rng, l, d, 6, 8.0));
        let k = Tensor::new(vec![l, d], channelwise_qk(&mut rng, l, d, 6, 8.0));
        let v = randn(vec![l, d], 77);
        let o_ref = reference::attention(&q, &k, &v, true);
        let err = |diag: usize, sink: usize| {
            let cfg = TileConfig { bm: 64, bn: 64, diag, sink, causal: true };
            let o = dma_attention(&q, &k, &v, &cfg);
            metrics::rmse(&o.data, &o_ref.data)
        };
        let e_low = err(0, 0);
        let e_dma = err(128, 64);
        assert!(e_dma < e_low, "dma {e_dma} vs pure-low {e_low}");
    }

    #[test]
    fn noncausal_phases_cover_everything() {
        // Non-causal with a huge window == all-high; compare against
        // diag=0 (all-low): both must be valid attention outputs
        // (rows of P sum to 1 -> outputs are convex combos of V rows).
        let (q, k, v) = qkv(128, 32, 11);
        for (diag, sink) in [(0usize, 0usize), (64, 32), (4096, 0)] {
            let cfg = TileConfig { bm: 32, bn: 32, diag, sink, causal: false };
            let o = dma_attention(&q, &k, &v, &cfg);
            for c in 0..32 {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for r in 0..128 {
                    lo = lo.min(v.at(r, c));
                    hi = hi.max(v.at(r, c));
                }
                for r in 0..128 {
                    let x = o.at(r, c);
                    assert!(x >= lo - 1e-4 && x <= hi + 1e-4,
                            "diag={diag} sink={sink}");
                }
            }
        }
    }

    #[test]
    fn tile_size_consistency() {
        let (q, k, v) = qkv(128, 64, 21);
        // With diag multiple of both tilings the high/low split differs
        // slightly at boundaries, but outputs must stay very close.
        let o1 = dma_attention(&q, &k, &v,
            &TileConfig { bm: 32, bn: 32, diag: 64, sink: 32, causal: true });
        let o2 = dma_attention(&q, &k, &v,
            &TileConfig { bm: 64, bn: 32, diag: 64, sink: 32, causal: true });
        assert!(metrics::cos_sim(&o1.data, &o2.data) > 0.999);
    }

    #[test]
    fn rectangular_prefill_shape() {
        let q = randn(vec![64, 64], 31);
        let k = randn(vec![256, 64], 32);
        let v = randn(vec![256, 64], 33);
        let cfg = TileConfig { bm: 64, bn: 64, diag: 128, sink: 64, causal: true };
        let o = dma_attention(&q, &k, &v, &cfg);
        let o_ref = reference::attention(&q, &k, &v, true);
        assert!(metrics::cos_sim(&o.data, &o_ref.data) > 0.99);
    }

    #[test]
    fn format_error_ordering_on_scores() {
        // Table 2 shape: MXFP4 much worse than NVFP4/MXFP8; DMA (ours)
        // comparable to MXFP8.
        let mut rng = Rng::new(55);
        let d = 64;
        let l = 128;
        let q = Tensor::new(vec![l, d], channelwise_qk(&mut rng, l, d, 6, 6.0));
        let k = Tensor::new(vec![l, d], channelwise_qk(&mut rng, l, d, 6, 6.0));
        let p_ref = reference::attention_scores(&q, &k, true);
        let cos = |f: Format| {
            let p = quantized_scores(&q, &k, f, false, true);
            metrics::cos_sim(&p_ref.data, &p.data)
        };
        let c4 = cos(Format::Mxfp4);
        let c8 = cos(Format::Mxfp8E4m3);
        let cn = cos(Format::Nvfp4);
        assert!(c8 > c4 && cn > c4, "mxfp4 {c4}, nvfp4 {cn}, mxfp8 {c8}");
    }

    #[test]
    fn property_output_rows_convex() {
        crate::util::prop::check("dma convexity", 10, |rng| {
            let l = 64;
            let d = 32;
            let q = Tensor::new(vec![l, d],
                (0..l * d).map(|_| rng.normal() as f32).collect());
            let k = Tensor::new(vec![l, d],
                (0..l * d).map(|_| rng.normal() as f32).collect());
            let v = Tensor::new(vec![l, d],
                (0..l * d).map(|_| rng.normal() as f32).collect());
            let diag = *rng.choose(&[0usize, 32, 64]);
            let sink = *rng.choose(&[0usize, 32]);
            let cfg = TileConfig { bm: 32, bn: 32, diag, sink, causal: true };
            let o = dma_attention(&q, &k, &v, &cfg);
            for c in 0..d {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for r in 0..l {
                    lo = lo.min(v.at(r, c));
                    hi = hi.max(v.at(r, c));
                }
                for r in 0..l {
                    let x = o.at(r, c);
                    crate::prop_assert!(
                        x >= lo - 1e-4 && x <= hi + 1e-4,
                        "row {r} col {c}: {x} outside [{lo}, {hi}]"
                    );
                }
            }
            Ok(())
        });
    }
}
