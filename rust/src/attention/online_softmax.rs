//! OnlineSoftmax (paper Sec. 3.2): streaming row-wise softmax
//! accumulation over KV tiles, maintaining the running maximum `m`,
//! normalizer `l`, and unnormalized output accumulator `O`.
//!
//! Both the flash and DMA kernels are built on this accumulator; it
//! supports base-e (`exp`) and base-2 (`exp2`) arithmetic — DMA folds
//! `log2(e)` into Q and runs in base-2 (Alg. 2, Step 1).

/// Streaming accumulator for one query tile of `rows` rows and head
/// dimension `d`.
pub struct OnlineSoftmax {
    pub rows: usize,
    pub d: usize,
    /// Running row maxima of the logits.
    pub m: Vec<f32>,
    /// Running normalizers.
    pub l: Vec<f32>,
    /// Unnormalized output accumulator [rows, d].
    pub acc: Vec<f32>,
    base2: bool,
}

impl OnlineSoftmax {
    pub fn new(rows: usize, d: usize, base2: bool) -> Self {
        OnlineSoftmax {
            rows,
            d,
            m: vec![f32::NEG_INFINITY; rows],
            l: vec![0.0; rows],
            acc: vec![0.0; rows * d],
            base2,
        }
    }

    #[inline]
    fn expf(&self, x: f32) -> f32 {
        if self.base2 {
            x.exp2()
        } else {
            x.exp()
        }
    }

    /// Fold in one KV tile: `s` is the [rows, bn] logit tile (already
    /// masked with -inf where invalid), `v` the [bn, d] value tile.
    /// `p_scratch` must have rows*bn capacity (reused across tiles to
    /// keep the hot loop allocation-free).
    pub fn update(&mut self, s: &[f32], v: &[f32], bn: usize, p_scratch: &mut [f32]) {
        debug_assert_eq!(s.len(), self.rows * bn);
        debug_assert_eq!(v.len(), bn * self.d);
        for r in 0..self.rows {
            let srow = &s[r * bn..(r + 1) * bn];
            let tile_max = srow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let m_new = self.m[r].max(tile_max);
            if m_new == f32::NEG_INFINITY {
                continue; // fully masked tile, nothing to accumulate
            }
            let alpha = if self.m[r] == f32::NEG_INFINITY {
                0.0
            } else {
                self.expf(self.m[r] - m_new)
            };
            let prow = &mut p_scratch[r * bn..(r + 1) * bn];
            let mut psum = 0.0f32;
            for (p, &sv) in prow.iter_mut().zip(srow) {
                let e = if sv == f32::NEG_INFINITY {
                    0.0
                } else {
                    self.expf(sv - m_new)
                };
                *p = e;
                psum += e;
            }
            self.l[r] = self.l[r] * alpha + psum;
            self.m[r] = m_new;
            let arow = &mut self.acc[r * self.d..(r + 1) * self.d];
            if alpha != 1.0 {
                crate::simd::scale_in_place(arow, alpha);
            }
            for (j, &p) in prow.iter().enumerate() {
                if p != 0.0 {
                    let vrow = &v[j * self.d..(j + 1) * self.d];
                    crate::simd::axpy(arow, p, vrow);
                }
            }
        }
    }

    /// Finalize: O = diag(l)^-1 acc, written into `out` [rows, d].
    pub fn finalize(&self, out: &mut [f32]) {
        for r in 0..self.rows {
            let inv = if self.l[r] > 0.0 { 1.0 / self.l[r] } else { 0.0 };
            for c in 0..self.d {
                out[r * self.d + c] = self.acc[r * self.d + c] * inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{randn, Tensor};

    /// Streaming over tiles must equal one-shot softmax.
    fn check_equivalence(base2: bool) {
        let (lq, lk, d, bn) = (8, 32, 16, 8);
        let q = randn(vec![lq, d], 1);
        let k = randn(vec![lk, d], 2);
        let v = randn(vec![lk, d], 3);
        let s_full = q.matmul_t(&k);

        let mut os = OnlineSoftmax::new(lq, d, base2);
        let mut scratch = vec![0f32; lq * bn];
        for t in 0..lk / bn {
            let mut s_tile = vec![0f32; lq * bn];
            for r in 0..lq {
                for j in 0..bn {
                    s_tile[r * bn + j] = s_full.at(r, t * bn + j);
                }
            }
            let v_tile = v.slice_rows(t * bn, (t + 1) * bn);
            os.update(&s_tile, &v_tile.data, bn, &mut scratch);
        }
        let mut out = vec![0f32; lq * d];
        os.finalize(&mut out);

        // One-shot reference with matching base.
        let s_scaled = if base2 {
            s_full.scale(std::f32::consts::LN_2)
        } else {
            s_full
        };
        let expect = s_scaled.softmax_rows().matmul(&v);
        for (a, b) in out.iter().zip(&expect.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b} (base2={base2})");
        }
    }

    #[test]
    fn equals_oneshot_base_e() {
        check_equivalence(false);
    }

    #[test]
    fn equals_oneshot_base_2() {
        check_equivalence(true);
    }

    #[test]
    fn tile_order_independent_result() {
        let (lq, lk, d, bn) = (4, 16, 8, 4);
        let q = randn(vec![lq, d], 4);
        let k = randn(vec![lk, d], 5);
        let v = randn(vec![lk, d], 6);
        let s_full = q.matmul_t(&k);

        let run = |order: &[usize]| {
            let mut os = OnlineSoftmax::new(lq, d, false);
            let mut scratch = vec![0f32; lq * bn];
            for &t in order {
                let mut s_tile = vec![0f32; lq * bn];
                for r in 0..lq {
                    for j in 0..bn {
                        s_tile[r * bn + j] = s_full.at(r, t * bn + j);
                    }
                }
                let v_tile = v.slice_rows(t * bn, (t + 1) * bn);
                os.update(&s_tile, &v_tile.data, bn, &mut scratch);
            }
            let mut out = vec![0f32; lq * d];
            os.finalize(&mut out);
            out
        };
        let a = run(&[0, 1, 2, 3]);
        let b = run(&[3, 1, 0, 2]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn fully_masked_tiles_ignored() {
        let (lq, d, bn) = (2, 4, 2);
        let mut os = OnlineSoftmax::new(lq, d, false);
        let mut scratch = vec![0f32; lq * bn];
        let masked = vec![f32::NEG_INFINITY; lq * bn];
        let v = Tensor::full(vec![bn, d], 1.0);
        os.update(&masked, &v.data, bn, &mut scratch);
        // Then a real tile.
        let s = vec![0.0f32; lq * bn];
        os.update(&s, &v.data, bn, &mut scratch);
        let mut out = vec![0f32; lq * d];
        os.finalize(&mut out);
        for &x in &out {
            assert!((x - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_accumulator_finalizes_to_zero() {
        let os = OnlineSoftmax::new(2, 4, false);
        let mut out = vec![7f32; 8];
        os.finalize(&mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
