//! Exact softmax attention — the naive oracle every tiled kernel is
//! validated against. Materializes the full score matrix; O(Lq·Lk·D).

use crate::tensor::Tensor;

/// Exact attention. q:[Lq,D], k,v:[Lk,D]. Causal alignment matches the
//  decoder convention: query i attends keys j <= i + (Lk - Lq).
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul_t(k).scale(scale);
    if causal {
        apply_causal_mask(&mut s, q.rows(), k.rows());
    }
    s.softmax_rows().matmul(v)
}

/// Post-softmax attention matrix P (for similarity metrics).
pub fn attention_scores(q: &Tensor, k: &Tensor, causal: bool) -> Tensor {
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul_t(k).scale(scale);
    if causal {
        apply_causal_mask(&mut s, q.rows(), k.rows());
    }
    s.softmax_rows()
}

/// Attention from precomputed base-2 logits (softmax scale already folded
/// into Q): softmax uses exp2 — the DMA kernels' convention.
pub fn attention_from_logits_base2(s: &Tensor, v: &Tensor, lq: usize, lk: usize,
                                   causal: bool) -> Tensor {
    let mut s = s.clone();
    if causal {
        apply_causal_mask(&mut s, lq, lk);
    }
    // exp2 softmax == exp softmax of ln2-scaled logits.
    let s = s.scale(std::f32::consts::LN_2);
    s.softmax_rows().matmul(v)
}

pub fn apply_causal_mask(s: &mut Tensor, lq: usize, lk: usize) {
    let off = lk as i64 - lq as i64;
    for i in 0..lq {
        let row = s.row_mut(i);
        for (j, val) in row.iter_mut().enumerate() {
            if j as i64 > i as i64 + off {
                *val = f32::NEG_INFINITY;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::randn;

    #[test]
    fn rows_are_convex_combinations() {
        let q = randn(vec![16, 32], 1);
        let k = randn(vec![16, 32], 2);
        let v = randn(vec![16, 32], 3);
        let o = attention(&q, &k, &v, true);
        // Each output row must lie within [min(v), max(v)] per column.
        for c in 0..32 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..16 {
                lo = lo.min(v.at(r, c));
                hi = hi.max(v.at(r, c));
            }
            for r in 0..16 {
                let x = o.at(r, c);
                assert!(x >= lo - 1e-5 && x <= hi + 1e-5);
            }
        }
    }

    #[test]
    fn first_row_causal_copies_v0() {
        let q = randn(vec![8, 16], 4);
        let k = randn(vec![8, 16], 5);
        let v = randn(vec![8, 16], 6);
        let o = attention(&q, &k, &v, true);
        // Query 0 can only attend key 0 -> output row 0 == v row 0.
        for c in 0..16 {
            assert!((o.at(0, c) - v.at(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    fn causality() {
        let q = randn(vec![8, 16], 7);
        let k = randn(vec![8, 16], 8);
        let v = randn(vec![8, 16], 9);
        let o1 = attention(&q, &k, &v, true);
        // Perturb key/value row 7; rows 0..7 of the output are unchanged.
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for c in 0..16 {
            k2.set(7, c, k2.at(7, c) + 5.0);
            v2.set(7, c, v2.at(7, c) - 3.0);
        }
        let o2 = attention(&q, &k2, &v2, true);
        for r in 0..7 {
            for c in 0..16 {
                assert_eq!(o1.at(r, c), o2.at(r, c));
            }
        }
    }

    #[test]
    fn rectangular_alignment() {
        // Lq=4, Lk=8: query 0 attends keys 0..=4.
        let q = randn(vec![4, 8], 10);
        let k = randn(vec![8, 8], 11);
        let v = randn(vec![8, 8], 12);
        let p = attention_scores(&q, &k, true);
        assert!(p.at(0, 4) > 0.0);
        assert_eq!(p.at(0, 5), 0.0);
    }

    #[test]
    fn scores_rows_sum_to_one() {
        let q = randn(vec![12, 16], 13);
        let k = randn(vec![12, 16], 14);
        let p = attention_scores(&q, &k, true);
        for r in 0..12 {
            let s: f32 = p.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn base2_logits_equivalent() {
        let q = randn(vec![8, 32], 15);
        let k = randn(vec![8, 32], 16);
        let v = randn(vec![8, 32], 17);
        let o1 = attention(&q, &k, &v, true);
        // Build base-2 logits by hand: S = (Q*log2e/sqrt(d)) K^T.
        let s = q.scale(std::f32::consts::LOG2_E / (32f32).sqrt()).matmul_t(&k);
        let o2 = attention_from_logits_base2(&s, &v, 8, 8, true);
        for (a, b) in o1.data.iter().zip(&o2.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
