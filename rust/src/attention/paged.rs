//! Paged-decode DMA attention: Algorithm 1's precision schedule applied
//! to the pages of an MXFP-quantized KV cache ([`crate::kvquant`]).
//!
//! One query tile (the trailing `lq` positions — `lq = 1` in serving
//! decode) attends over the cache page by page: each page's K rows are
//! dequantized into a scratch tile at the precision the [`KvPolicy`]
//! assigns (sink / frontier pages high, body pages low, clamped to the
//! copies the cache's [`KvFormat`] retains), V pages decode at the
//! highest retained precision, and everything is stitched with base-2
//! [`OnlineSoftmax`]. No full-precision K/V is ever materialized — the
//! scratch footprint is one page.
//!
//! When the cache length is a multiple of the page size and the policy
//! mirrors a [`super::TileConfig`] (`bn = page_tokens`, same sink/diag),
//! the result is **bit-exact** with [`super::dma::dma_attention_quantized`]
//! on the equivalent contiguous layout: both paths share the same row
//! decoders, the same [`score_tile`] arithmetic and the same accumulator
//! update order (see `paged_bit_exact_with_contiguous_kernel` below).
//!
//! [`KvPolicy`]: crate::kvquant::KvPolicy
//! [`KvFormat`]: crate::kvquant::KvFormat

use super::dma::score_tile;
use super::online_softmax::OnlineSoftmax;
use crate::kvquant::{DecodedPageCache, KvPolicy, Precision, QuantPagedKv};
use crate::metrics::KvPageStats;
use crate::mxfp::block::Granularity;
use crate::mxfp::fused::{dual_quant, DualQuantized};
use crate::mxfp::LOG2_E;
use crate::tensor::Tensor;

/// Mixed-precision attention of the dual-quantized query tile `qq`
/// (`is_query=true` output of [`crate::mxfp::fused::dual_quant`], the
/// trailing `qq.rows` positions of the sequence) over a quantized paged
/// K/V cache. Causal; returns `[lq, d]`. Page decode counts are
/// accumulated into `stats`.
pub fn dma_attention_paged(
    qq: &DualQuantized,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    stats: &mut KvPageStats,
) -> Tensor {
    let len = k.len();
    assert!(len >= qq.rows, "cache len {len} < query rows {}", qq.rows);
    // Query row r sits at absolute position len - lq + r.
    paged_attention_impl(qq, k, v, policy, (len - qq.rows) as i64, None, stats)
}

/// GQA decode variant: every row of `qq` is an independent query *head*
/// at the causal frontier (position `len - 1`) — the
/// `n_heads / n_kv_heads` query heads that share one kv head. Each cache
/// page is dequantized once for the whole head group instead of once per
/// head. Bit-identical to calling [`dma_attention_paged`] per head row.
pub fn dma_attention_paged_heads(
    qq: &DualQuantized,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    stats: &mut KvPageStats,
) -> Tensor {
    let len = k.len();
    assert!(len >= 1, "empty cache");
    // All rows share the frontier position: no key is ever masked.
    paged_attention_impl(qq, k, v, policy, len as i64 - 1, None, stats)
}

/// [`dma_attention_paged_heads`] backed by a [`DecodedPageCache`]: full
/// (immutable) K and V pages dequantize through the cache, so a steady
/// decode re-dequantizes only the partial frontier page each token —
/// O(frontier) instead of O(context). Bit-identical to the uncached
/// call: cached tiles are produced by the same decoders from the same
/// immutable bytes.
pub fn dma_attention_paged_heads_cached(
    qq: &DualQuantized,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    cache: &mut DecodedPageCache,
    stats: &mut KvPageStats,
) -> Tensor {
    let len = k.len();
    assert!(len >= 1, "empty cache");
    paged_attention_impl(qq, k, v, policy, len as i64 - 1, Some(cache), stats)
}

fn paged_attention_impl(
    qq: &DualQuantized,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    q_pos0: i64,
    mut cache: Option<&mut DecodedPageCache>,
    stats: &mut KvPageStats,
) -> Tensor {
    let (lq, d) = (qq.rows, qq.d);
    let len = k.len();
    assert!(lq >= 1, "empty query tile");
    assert_eq!(k.d(), d, "K width");
    assert_eq!(v.d(), d, "V width");
    assert_eq!(v.len(), len, "K/V length mismatch");
    let pt = k.page_tokens;
    assert_eq!(v.page_tokens, pt, "K/V page size mismatch");

    // Decode both precision copies of the query tile once.
    let mut q_low = vec![0f32; lq * d];
    let mut q_high = vec![0f32; lq * d];
    qq.decode_low_rows(0, lq, &mut q_low);
    qq.decode_high_rows(0, lq, &mut q_high);

    let schedule = policy.page_precisions(len, pt);

    let mut os = OnlineSoftmax::new(lq, d, true);
    // Hot-loop scratch: one page. The decode tiles are lazy — with a
    // warm cache and a page-aligned context every page is served from
    // it and the buffers are never needed.
    let mut k_tile: Vec<f32> = Vec::new();
    let mut v_tile: Vec<f32> = Vec::new();
    let mut s_tile = vec![0f32; lq * pt];
    let mut scratch = vec![0f32; lq * pt];

    for (j, &prec) in schedule.iter().enumerate() {
        let (r0, r1) = k.page_rows(j);
        let cols = r1 - r0;
        // Per-page clamp: a precision-aged shared page serves low even
        // when the store format carries both copies (kvquant::tier).
        let eff = k.effective_at(j, prec);
        match eff {
            Precision::High => stats.high_pages += 1,
            Precision::Low => stats.low_pages += 1,
        }
        // Full pages are immutable: serve their decoded tiles from the
        // cache when one is attached. The partial frontier page decodes
        // fresh every step (it grows in place).
        let k_dec: &[f32] = match cache.as_deref_mut() {
            Some(c) if j < k.n_full_pages() => c.get_or_decode(k.page_arc(j), eff, stats),
            _ => {
                k_tile.resize(pt * d, 0.0);
                k.decode_rows(r0, r1, eff, &mut k_tile);
                &k_tile
            }
        };
        let q_dec = if eff == Precision::High { &q_high } else { &q_low };
        score_tile(q_dec, lq, d, k_dec, cols, q_pos0, r0, true, &mut s_tile);
        let v_eff = v.effective_at(j, Precision::High);
        let v_dec: &[f32] = match cache.as_deref_mut() {
            Some(c) if j < v.n_full_pages() => c.get_or_decode(v.page_arc(j), v_eff, stats),
            _ => {
                v_tile.resize(pt * d, 0.0);
                v.decode_rows(r0, r1, Precision::High, &mut v_tile);
                &v_tile
            }
        };
        os.update(&s_tile[..lq * cols], &v_dec[..cols * d], cols, &mut scratch);
    }

    let mut out = Tensor::zeros(vec![lq, d]);
    os.finalize(&mut out.data);
    out
}

/// Chunked-prefill attention over a quantized prefix: the chunk's f32
/// query rows sit at absolute positions `[pos0, pos0 + lq)` where
/// `pos0 = k.len()` and `lq = k_chunk.rows()` — everything already in
/// the cache is prefix, the chunk's own K/V tiles arrive in f32
/// (`k_chunk`/`v_chunk`, `[lq, d]`) and are appended by the caller
/// *after* this call.
///
/// GQA head grouping: `q` may stack the `n_heads / n_kv_heads` query
/// heads sharing this kv head as consecutive `[lq, d]` tiles
/// (`q.rows() = heads * lq`; row `h*lq + r` sits at position
/// `pos0 + r`). Each prefix page then decodes once for the whole group
/// instead of once per head — the prefill analogue of
/// [`dma_attention_paged_heads`] — and the result is bit-identical to
/// per-head calls (online-softmax rows are independent).
///
/// Prefix pages decode at the position-aware policy precision
/// ([`KvPolicy::page_precisions_at`] with the chunk's causal frontier
/// `pos0 + lq - 1`), scored against the dual-quantized query copy of the
/// matching precision — exactly the decode kernel's arithmetic. The
/// in-chunk causal triangle is scored in f32 with the base-2 softmax
/// scale folded in, and everything is stitched with one base-2
/// [`OnlineSoftmax`]. Prefix V decodes high; chunk V stays f32.
///
/// This is the kernel behind [`crate::model::CpuModel::prefill_chunk_quant`];
/// the Python parity reference is
/// `python/compile/kernels/kv_quant.py::chunked_prefill_attention` (cross
/// checked bit-level through `rust/testdata/golden_kvquant.json`).
pub fn dma_attention_prefill_chunk(
    q: &Tensor,
    k_chunk: &Tensor,
    v_chunk: &Tensor,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    stats: &mut KvPageStats,
) -> Tensor {
    prefill_chunk_impl(q, k_chunk, v_chunk, k, v, policy, None, stats)
}

/// [`dma_attention_prefill_chunk`] backed by a [`DecodedPageCache`]:
/// full prefix K/V pages dequantize through the cache, so a sequence
/// prefilled in `c` chunks decodes each prefix page once instead of
/// once per chunk — and when the cache handle is the slot's
/// (`QuantSlotKv.decoded`), the decode steps that follow inherit the
/// warm tiles. Bit-identical to the uncached call: cached tiles come
/// from the same decoders over the same immutable bytes. The partial
/// frontier page (growing in place between chunks) always decodes
/// fresh.
pub fn dma_attention_prefill_chunk_cached(
    q: &Tensor,
    k_chunk: &Tensor,
    v_chunk: &Tensor,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    cache: &mut DecodedPageCache,
    stats: &mut KvPageStats,
) -> Tensor {
    prefill_chunk_impl(q, k_chunk, v_chunk, k, v, policy, Some(cache), stats)
}

fn prefill_chunk_impl(
    q: &Tensor,
    k_chunk: &Tensor,
    v_chunk: &Tensor,
    k: &QuantPagedKv,
    v: &QuantPagedKv,
    policy: &KvPolicy,
    mut cache: Option<&mut DecodedPageCache>,
    stats: &mut KvPageStats,
) -> Tensor {
    let (rows, d) = (q.rows(), q.cols());
    let lq = k_chunk.rows();
    assert!(lq >= 1, "empty chunk");
    assert!(rows >= lq && rows % lq == 0, "q rows {rows} not a multiple of chunk {lq}");
    assert_eq!(v_chunk.rows(), lq, "chunk V rows");
    assert_eq!(k.d(), d, "K width");
    assert_eq!(v.d(), d, "V width");
    let pos0 = k.len();
    assert_eq!(v.len(), pos0, "K/V prefix length mismatch");
    let pt = k.page_tokens;
    assert_eq!(v.page_tokens, pt, "K/V page size mismatch");

    // Quantize the chunk queries once (softmax scale folded, base-2) and
    // decode both precision copies, mirroring the decode kernel.
    let qq = dual_quant(&q.data, rows, d, true, Granularity::PerToken);
    let mut q_low = vec![0f32; rows * d];
    let mut q_high = vec![0f32; rows * d];
    qq.decode_low_rows(0, rows, &mut q_low);
    qq.decode_high_rows(0, rows, &mut q_high);

    let mut os = OnlineSoftmax::new(rows, d, true);
    // Lazy decode tiles, mirroring the decode path: with a warm cache
    // and a page-aligned prefix they are never allocated.
    let mut k_tile: Vec<f32> = Vec::new();
    let mut v_tile: Vec<f32> = Vec::new();
    let mut s_tile = vec![0f32; rows * pt.max(lq)];
    let mut scratch = vec![0f32; rows * pt.max(lq)];

    // Prefix pages at the position-aware precision. No causal masking:
    // every prefix key precedes every chunk query.
    let schedule = policy.page_precisions_at(pos0 + lq - 1, pos0, pt);
    for (j, &prec) in schedule.iter().enumerate() {
        let (r0, r1) = k.page_rows(j);
        let cols = r1 - r0;
        // Per-page clamp: a precision-aged shared page serves low even
        // when the store format carries both copies (kvquant::tier).
        let eff = k.effective_at(j, prec);
        match eff {
            Precision::High => stats.high_pages += 1,
            Precision::Low => stats.low_pages += 1,
        }
        // Full prefix pages are immutable within and across chunks:
        // serve them from the cache when one is attached. The partial
        // frontier page grows between chunks and decodes fresh.
        let k_dec: &[f32] = match cache.as_deref_mut() {
            Some(c) if j < k.n_full_pages() => c.get_or_decode(k.page_arc(j), eff, stats),
            _ => {
                k_tile.resize(pt * d, 0.0);
                k.decode_rows(r0, r1, eff, &mut k_tile);
                &k_tile
            }
        };
        let q_dec = if eff == Precision::High { &q_high } else { &q_low };
        score_tile(q_dec, rows, d, k_dec, cols, pos0 as i64, r0, false,
                   &mut s_tile[..rows * cols]);
        let v_eff = v.effective_at(j, Precision::High);
        let v_dec: &[f32] = match cache.as_deref_mut() {
            Some(c) if j < v.n_full_pages() => c.get_or_decode(v.page_arc(j), v_eff, stats),
            _ => {
                v_tile.resize(pt * d, 0.0);
                v.decode_rows(r0, r1, Precision::High, &mut v_tile);
                &v_tile
            }
        };
        os.update(&s_tile[..rows * cols], &v_dec[..cols * d], cols, &mut scratch);
    }

    // The chunk's own causal triangle in f32, base-2 logits: fold the
    // softmax scale into the raw queries the same way the quantizer does
    // for the prefix scores. Row h*lq + r is query position pos0 + r.
    let pre = LOG2_E / (d as f32).sqrt();
    for r in 0..rows {
        let rr = r % lq;
        for c in 0..lq {
            s_tile[r * lq + c] = if c > rr {
                f32::NEG_INFINITY
            } else {
                let mut acc = 0f32;
                for (a, b) in q.row(r).iter().zip(k_chunk.row(c)) {
                    acc += a * b;
                }
                acc * pre
            };
        }
    }
    os.update(&s_tile[..rows * lq], &v_chunk.data, lq, &mut scratch);

    let mut out = Tensor::zeros(vec![rows, d]);
    os.finalize(&mut out.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dma::dma_attention_quantized;
    use crate::attention::TileConfig;
    use crate::kvquant::KvFormat;
    use crate::mxfp::block::Granularity;
    use crate::mxfp::fused::dual_quant;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * d).map(|_| rng.normal() as f32).collect()
    }

    fn filled(n: usize, d: usize, fmt: KvFormat, pt: usize, seed: u64) -> QuantPagedKv {
        let mut s = QuantPagedKv::new(d, fmt, pt);
        let x = rows(n, d, seed);
        // Append in uneven chunks to exercise the chunking invariance.
        let mut i = 0;
        for ch in [n / 2, n / 4, n - n / 2 - n / 4] {
            s.append_rows(&x[i * d..(i + ch) * d]);
            i += ch;
        }
        s
    }

    fn decode_all_high(s: &QuantPagedKv) -> Tensor {
        let (n, d) = (s.len(), s.d());
        let mut out = Tensor::zeros(vec![n, d]);
        s.decode_rows(0, n, Precision::High, &mut out.data);
        out
    }

    #[test]
    fn paged_bit_exact_with_contiguous_kernel() {
        // The acceptance-bar test: over a dual-format cache whose length
        // is a page multiple, the paged path must equal the contiguous
        // DMA kernel bit for bit on the equivalent contiguous layout.
        let (n, d, pt) = (64usize, 32usize, 8usize);
        let k = filled(n, d, KvFormat::Dual, pt, 1);
        let v = filled(n, d, KvFormat::Dual, pt, 2);
        for (lq, sink, diag) in [
            (1usize, 8usize, 16usize),
            (1, 0, 0),
            (1, 16, 0),
            (1, 0, 32),
            (8, 8, 16),
            (8, 64, 64),
        ] {
            let q = rows(lq, d, 100 + (lq + sink + diag) as u64);
            let qq = dual_quant(&q, lq, d, true, Granularity::PerToken);
            let policy = KvPolicy { sink, diag };
            let mut stats = KvPageStats::default();
            let paged = dma_attention_paged(&qq, &k, &v, &policy, &mut stats);
            assert_eq!(stats.total(), (n / pt) as u64);

            // Contiguous layout: identical K planes (chunking invariance)
            // and V as the exact high dequantization the paged path uses.
            let kq = dual_quant(&rows(n, d, 1), n, d, false, Granularity::PerToken);
            assert_eq!(kq.packed_fp4, k.planes().packed_fp4);
            assert_eq!(kq.fp8_codes, k.planes().fp8_codes);
            let v_eq = decode_all_high(&v);
            let cfg = TileConfig { bm: lq, bn: pt, diag, sink, causal: true };
            let contiguous = dma_attention_quantized(&qq, &kq, &v_eq, &cfg);
            assert_eq!(
                paged.data, contiguous.data,
                "lq={lq} sink={sink} diag={diag}"
            );
        }
    }

    #[test]
    fn head_grouped_variant_bit_matches_per_head_calls() {
        // GQA grouping: one multi-row frontier call must equal per-head
        // single-row calls bit for bit, with 1/n_rep the page decodes.
        let (n, d, pt, n_rep) = (40usize, 32usize, 8usize, 4usize);
        let k = filled(n, d, KvFormat::Dual, pt, 20);
        let v = filled(n, d, KvFormat::Dual, pt, 21);
        let policy = KvPolicy { sink: 8, diag: 16 };
        let heads = rows(n_rep, d, 22);

        let qq_group = dual_quant(&heads, n_rep, d, true, Granularity::PerToken);
        let mut s_group = KvPageStats::default();
        let grouped = dma_attention_paged_heads(&qq_group, &k, &v, &policy, &mut s_group);

        let mut s_single = KvPageStats::default();
        for h in 0..n_rep {
            let qq = dual_quant(&heads[h * d..(h + 1) * d], 1, d, true, Granularity::PerToken);
            let one = dma_attention_paged(&qq, &k, &v, &policy, &mut s_single);
            assert_eq!(one.data, grouped.row(h).to_vec(), "head {h}");
        }
        // Grouping decodes each page once instead of n_rep times.
        assert_eq!(s_single.total(), n_rep as u64 * s_group.total());
    }

    #[test]
    fn property_cached_attention_bit_identical_to_cold_decode() {
        // Across random formats, policies, lengths and budgets: the
        // cache-backed kernel must equal the cold kernel bit for bit —
        // cold cache, warm cache, after evictions, and as the store
        // grows (precision flips at the moving frontier included).
        crate::util::prop::check("decoded-page cache bit-exact", 20, |rng| {
            let d = 32 * (1 + rng.below(2) as usize);
            let pt = *rng.choose(&[4usize, 8, 16]);
            let fmt = *rng.choose(&[KvFormat::Dual, KvFormat::Mxfp8, KvFormat::Nvfp4]);
            let policy = KvPolicy {
                sink: *rng.choose(&[0usize, 8, 16]),
                diag: *rng.choose(&[0usize, 8, 32]),
            };
            let n0 = pt * (2 + rng.below(4) as usize) + rng.below(pt as u64) as usize;
            let n_rep = 1 + rng.below(4) as usize;
            // Budget sometimes too small for everything -> evictions.
            let budget = *rng.choose(&[256usize, 4096, 1 << 20]);
            let mut k = QuantPagedKv::new(d, fmt, pt);
            let mut v = QuantPagedKv::new(d, fmt, pt);
            let seed = rng.below(1 << 30);
            k.append_rows(&rows(n0, d, seed));
            v.append_rows(&rows(n0, d, seed + 1));
            let mut cache = crate::kvquant::DecodedPageCache::new(budget);
            let mut s_cold = KvPageStats::default();
            let mut s_warm = KvPageStats::default();
            for step in 0..4 {
                let q = rows(n_rep, d, seed + 10 + step);
                let qq = dual_quant(&q, n_rep, d, true, Granularity::PerToken);
                let cold = dma_attention_paged_heads(&qq, &k, &v, &policy, &mut s_cold);
                let cached = dma_attention_paged_heads_cached(
                    &qq, &k, &v, &policy, &mut cache, &mut s_warm);
                crate::prop_assert!(
                    cold.data == cached.data,
                    "step {} diverged (fmt {:?} pt {} budget {})",
                    step, fmt, pt, budget
                );
                crate::prop_assert!(
                    cache.bytes() <= cache.budget_bytes(),
                    "cache over budget: {} > {}",
                    cache.bytes(), cache.budget_bytes()
                );
                // Grow the store so the frontier (and the diag window)
                // moves between steps.
                let g = rows(1, d, seed + 50 + step);
                k.append_rows(&g);
                v.append_rows(&g);
            }
            // Page-visit counters are identical with and without cache.
            crate::prop_assert!(
                (s_cold.high_pages, s_cold.low_pages) == (s_warm.high_pages, s_warm.low_pages),
                "visit counters diverged: {s_cold:?} vs {s_warm:?}"
            );
            crate::prop_assert!(
                s_warm.cache_hits + s_warm.cache_misses > 0,
                "cache never consulted"
            );
            Ok(())
        });
    }

    #[test]
    fn cached_decode_amortizes_to_frontier_only() {
        // Steady-state decode over a page-aligned prefix: after the
        // first (cold) step, every full K and V page hits; only the
        // growing frontier page misses.
        let (n, d, pt) = (64usize, 32usize, 8usize);
        let k0 = filled(n, d, KvFormat::Dual, pt, 70);
        let v0 = filled(n, d, KvFormat::Dual, pt, 71);
        let (mut k, mut v) = (k0.fork(), v0.fork());
        let policy = KvPolicy { sink: 8, diag: 16 };
        let mut cache = crate::kvquant::DecodedPageCache::new(1 << 20);
        let mut stats = KvPageStats::default();
        let step = |k: &QuantPagedKv, v: &QuantPagedKv,
                    cache: &mut crate::kvquant::DecodedPageCache,
                    stats: &mut KvPageStats, seed: u64| {
            let q = rows(2, d, seed);
            let qq = dual_quant(&q, 2, d, true, Granularity::PerToken);
            dma_attention_paged_heads_cached(&qq, k, v, &policy, cache, stats)
        };
        step(&k, &v, &mut cache, &mut stats, 100);
        assert_eq!(stats.cache_hits, 0, "cold step cannot hit");
        assert_eq!(stats.cache_misses, 2 * (n / pt) as u64); // K + V pages
        // Second step, same geometry: all full pages hit.
        let cold_misses = stats.cache_misses;
        step(&k, &v, &mut cache, &mut stats, 101);
        assert_eq!(stats.cache_misses, cold_misses, "warm step re-decoded a full page");
        assert_eq!(stats.cache_hits, 2 * (n / pt) as u64);
        // Growing a partial frontier page: it misses, full pages hit.
        k.append_rows(&rows(1, d, 102));
        v.append_rows(&rows(1, d, 102));
        let (h0, m0) = (stats.cache_hits, stats.cache_misses);
        step(&k, &v, &mut cache, &mut stats, 103);
        assert_eq!(stats.cache_hits - h0, 2 * (n / pt) as u64);
        assert_eq!(stats.cache_misses, m0, "partial frontier page must bypass the cache");
        assert_eq!(stats.cache_evictions, 0);
    }

    #[test]
    fn page_hit_counters_follow_policy() {
        let (n, d, pt) = (64usize, 32usize, 8usize);
        let k = filled(n, d, KvFormat::Dual, pt, 3);
        let v = filled(n, d, KvFormat::Dual, pt, 4);
        let q = rows(1, d, 5);
        let qq = dual_quant(&q, 1, d, true, Granularity::PerToken);
        let mut stats = KvPageStats::default();
        dma_attention_paged(&qq, &k, &v, &KvPolicy { sink: 8, diag: 16 }, &mut stats);
        // 1 sink page + 2 frontier pages high, 5 body pages low.
        assert_eq!(stats, KvPageStats { high_pages: 3, low_pages: 5, ..Default::default() });
        assert!((stats.high_fraction() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn single_format_cache_ignores_policy() {
        // nvfp4-low: every page decodes low regardless of sink/diag, so
        // the result equals a dual cache under the all-low policy with V
        // decoded low on both sides.
        let (n, d, pt) = (48usize, 32usize, 8usize);
        let k_lo = filled(n, d, KvFormat::Nvfp4, pt, 6);
        let v_lo = filled(n, d, KvFormat::Nvfp4, pt, 7);
        let k_du = filled(n, d, KvFormat::Dual, pt, 6);
        let v_du = filled(n, d, KvFormat::Dual, pt, 7);
        // Sanity: low planes identical across formats.
        assert_eq!(k_lo.planes().packed_fp4, k_du.planes().packed_fp4);

        let q = rows(1, d, 8);
        let qq = dual_quant(&q, 1, d, true, Granularity::PerToken);
        let mut s1 = KvPageStats::default();
        let o_lo = dma_attention_paged(&qq, &k_lo, &v_lo, &KvPolicy { sink: 8, diag: 16 }, &mut s1);
        assert_eq!(s1.high_pages, 0);

        // Dual oracle: all-low policy; force V low by rebuilding the V
        // store in nvfp4 (same planes as v_du's low copy).
        let mut s2 = KvPageStats::default();
        let o_du = dma_attention_paged(&qq, &k_du, &v_lo, &KvPolicy { sink: 0, diag: 0 }, &mut s2);
        assert_eq!(o_lo.data, o_du.data);

        // mxfp8-high: everything decodes high.
        let k_hi = filled(n, d, KvFormat::Mxfp8, pt, 6);
        let v_hi = filled(n, d, KvFormat::Mxfp8, pt, 7);
        let mut s3 = KvPageStats::default();
        let o_hi = dma_attention_paged(&qq, &k_hi, &v_hi, &KvPolicy { sink: 0, diag: 0 }, &mut s3);
        assert_eq!(s3.low_pages, 0);
        let mut s4 = KvPageStats::default();
        let o_du_hi =
            dma_attention_paged(&qq, &k_du, &v_du, &KvPolicy { sink: 0, diag: usize::MAX / 2 }, &mut s4);
        assert_eq!(o_hi.data, o_du_hi.data);
    }

    #[test]
    fn partial_frontier_page_matches_dense_oracle() {
        // Cache length not a multiple of the page size: compare against a
        // one-shot softmax over the page-mixed decoded operands.
        let (n, d, pt) = (27usize, 32usize, 8usize);
        let k = filled(n, d, KvFormat::Dual, pt, 9);
        let v = filled(n, d, KvFormat::Dual, pt, 10);
        let q = rows(1, d, 11);
        let qq = dual_quant(&q, 1, d, true, Granularity::PerToken);
        let policy = KvPolicy { sink: 8, diag: 16 };
        let mut stats = KvPageStats::default();
        let out = dma_attention_paged(&qq, &k, &v, &policy, &mut stats);
        assert_eq!(stats.total(), 4); // ceil(27 / 8) pages

        let mut ql = vec![0f32; d];
        let mut qh = vec![0f32; d];
        qq.decode_low_rows(0, 1, &mut ql);
        qq.decode_high_rows(0, 1, &mut qh);
        let mut s = vec![0f32; n];
        let mut k_tile = vec![0f32; pt * d];
        for (j, &prec) in policy.page_precisions(n, pt).iter().enumerate() {
            let (r0, r1) = k.page_rows(j);
            k.decode_rows(r0, r1, prec, &mut k_tile);
            let qd = if prec == Precision::High { &qh } else { &ql };
            for c in 0..r1 - r0 {
                s[r0 + c] = k_tile[c * d..(c + 1) * d]
                    .iter()
                    .zip(qd)
                    .map(|(a, b)| a * b)
                    .sum();
            }
        }
        let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let p: Vec<f32> = s.iter().map(|&x| (x - m).exp2()).collect();
        let z: f32 = p.iter().sum();
        let v_all = decode_all_high(&v);
        for c in 0..d {
            let mut acc = 0f32;
            for (j, &pj) in p.iter().enumerate() {
                acc += pj * v_all.at(j, c);
            }
            let expect = acc / z;
            assert!(
                (out.at(0, c) - expect).abs() < 1e-4,
                "col {c}: {} vs {expect}",
                out.at(0, c)
            );
        }
    }

    #[test]
    fn prefill_chunk_matches_dense_oracle() {
        // A chunk of 8 queries at positions [24, 32) over a 24-token
        // quantized prefix: compare against a one-shot base-2 softmax
        // over the page-mixed prefix + f32 chunk operands.
        let (pos0, lq, d, pt) = (24usize, 8usize, 32usize, 8usize);
        let k = filled(pos0, d, KvFormat::Dual, pt, 40);
        let v = filled(pos0, d, KvFormat::Dual, pt, 41);
        let q = Tensor::new(vec![lq, d], rows(lq, d, 42));
        let kc = Tensor::new(vec![lq, d], rows(lq, d, 43));
        let vc = Tensor::new(vec![lq, d], rows(lq, d, 44));
        let policy = KvPolicy { sink: 8, diag: 16 };
        let mut stats = KvPageStats::default();
        let out = dma_attention_prefill_chunk(&q, &kc, &vc, &k, &v, &policy, &mut stats);
        assert_eq!(stats.total(), (pos0 / pt) as u64);

        // Oracle: decode prefix K at the position-aware schedule, stack
        // the f32 chunk, one-shot exp2 softmax per query row.
        let qq = dual_quant(&q.data, lq, d, true, Granularity::PerToken);
        let mut ql = vec![0f32; lq * d];
        let mut qh = vec![0f32; lq * d];
        qq.decode_low_rows(0, lq, &mut ql);
        qq.decode_high_rows(0, lq, &mut qh);
        let sched = policy.page_precisions_at(pos0 + lq - 1, pos0, pt);
        let pre = crate::mxfp::LOG2_E / (d as f32).sqrt();
        let n = pos0 + lq;
        let mut v_all = vec![0f32; n * d];
        v.decode_rows(0, pos0, Precision::High, &mut v_all[..pos0 * d]);
        v_all[pos0 * d..].copy_from_slice(&vc.data);
        for r in 0..lq {
            let mut s = vec![f32::NEG_INFINITY; n];
            let mut k_tile = vec![0f32; pt * d];
            for (j, &prec) in sched.iter().enumerate() {
                let (r0, r1) = k.page_rows(j);
                k.decode_rows(r0, r1, prec, &mut k_tile);
                let qd = if prec == Precision::High { &qh } else { &ql };
                for c in 0..r1 - r0 {
                    s[r0 + c] = k_tile[c * d..(c + 1) * d]
                        .iter()
                        .zip(&qd[r * d..(r + 1) * d])
                        .map(|(a, b)| a * b)
                        .sum();
                }
            }
            for c in 0..=r {
                s[pos0 + c] = kc.row(c)
                    .iter()
                    .zip(q.row(r))
                    .map(|(a, b)| a * b)
                    .sum::<f32>()
                    * pre;
            }
            let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let p: Vec<f32> = s.iter().map(|&x| if x == f32::NEG_INFINITY { 0.0 } else { (x - m).exp2() }).collect();
            let z: f32 = p.iter().sum();
            for c in 0..d {
                let mut acc = 0f32;
                for (j, &pj) in p.iter().enumerate() {
                    acc += pj * v_all[j * d + c];
                }
                let expect = acc / z;
                assert!(
                    (out.at(r, c) - expect).abs() < 1e-4,
                    "row {r} col {c}: {} vs {expect}",
                    out.at(r, c)
                );
            }
        }
    }

    #[test]
    fn prefill_chunk_empty_prefix_is_pure_f32_tile() {
        // pos0 = 0: no pages, only the causal f32 triangle — equals the
        // exact base-2 reference on the chunk operands.
        let (lq, d) = (8usize, 32usize);
        let q = Tensor::new(vec![lq, d], rows(lq, d, 50));
        let kc = Tensor::new(vec![lq, d], rows(lq, d, 51));
        let vc = Tensor::new(vec![lq, d], rows(lq, d, 52));
        let k = QuantPagedKv::new(d, KvFormat::Dual, 8);
        let v = QuantPagedKv::new(d, KvFormat::Dual, 8);
        let mut stats = KvPageStats::default();
        let out = dma_attention_prefill_chunk(
            &q, &kc, &vc, &k, &v, &KvPolicy { sink: 8, diag: 8 }, &mut stats);
        assert_eq!(stats.total(), 0);
        let pre = crate::mxfp::LOG2_E / (d as f32).sqrt();
        let s = q.scale(pre).matmul_t(&kc);
        let expect = crate::attention::reference::attention_from_logits_base2(
            &s, &vc, lq, lq, true);
        for (a, b) in out.data.iter().zip(&expect.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_chunk_head_grouping_bit_matches_per_head_calls() {
        // GQA grouping for the prefill kernel: stacking n_rep head tiles
        // into one call must equal per-head calls bit for bit, with
        // 1/n_rep the page decodes (same contract as
        // dma_attention_paged_heads).
        let (pos0, lq, d, pt, n_rep) = (24usize, 4usize, 32usize, 8usize, 4usize);
        let k = filled(pos0, d, KvFormat::Dual, pt, 90);
        let v = filled(pos0, d, KvFormat::Dual, pt, 91);
        let kc = Tensor::new(vec![lq, d], rows(lq, d, 92));
        let vc = Tensor::new(vec![lq, d], rows(lq, d, 93));
        let heads = rows(n_rep * lq, d, 94);
        let policy = KvPolicy { sink: 8, diag: 16 };

        let qs = Tensor::new(vec![n_rep * lq, d], heads.clone());
        let mut s_group = KvPageStats::default();
        let grouped = dma_attention_prefill_chunk(&qs, &kc, &vc, &k, &v, &policy, &mut s_group);

        let mut s_single = KvPageStats::default();
        for h in 0..n_rep {
            let qh = Tensor::new(vec![lq, d], heads[h * lq * d..(h + 1) * lq * d].to_vec());
            let one =
                dma_attention_prefill_chunk(&qh, &kc, &vc, &k, &v, &policy, &mut s_single);
            for r in 0..lq {
                assert_eq!(one.row(r), grouped.row(h * lq + r), "head {h} row {r}");
            }
        }
        assert_eq!(s_single.total(), n_rep as u64 * s_group.total());
    }

    #[test]
    fn cached_prefill_chunks_bit_identical_and_reuse_prefix() {
        // Prefill a 40-token prompt in 5 chunks of 8 over a growing
        // dual-format prefix (pt = 8, so every prefix page is full).
        // The cached kernel must equal the uncached one bit for bit,
        // and re-decode only pages it has never seen: with sink=0,
        // diag=0 every K page decodes low at every chunk, so each of
        // the 4 distinct prefix pages misses exactly once per store
        // (K + V = 8 misses) and the other 12 page-visits per store
        // pair hit (10 + 10 visits total, 12 hits).
        let (d, pt, lq, n_chunks) = (32usize, 8usize, 8usize, 5usize);
        let prompt_q = rows(n_chunks * lq, d, 120);
        let prompt_k = rows(n_chunks * lq, d, 121);
        let prompt_v = rows(n_chunks * lq, d, 122);
        let policy = KvPolicy { sink: 0, diag: 0 };

        let run = |cache: Option<&mut DecodedPageCache>, stats: &mut KvPageStats| {
            let mut cache = cache;
            let mut k = QuantPagedKv::new(d, KvFormat::Dual, pt);
            let mut v = QuantPagedKv::new(d, KvFormat::Dual, pt);
            let mut outs: Vec<Vec<f32>> = Vec::new();
            for c in 0..n_chunks {
                let sl = |p: &[f32]| p[c * lq * d..(c + 1) * lq * d].to_vec();
                let q = Tensor::new(vec![lq, d], sl(&prompt_q));
                let kc = Tensor::new(vec![lq, d], sl(&prompt_k));
                let vc = Tensor::new(vec![lq, d], sl(&prompt_v));
                let out = match cache.as_deref_mut() {
                    Some(cc) => dma_attention_prefill_chunk_cached(
                        &q, &kc, &vc, &k, &v, &policy, cc, stats),
                    None => dma_attention_prefill_chunk(
                        &q, &kc, &vc, &k, &v, &policy, stats),
                };
                outs.push(out.data);
                k.append_rows(&kc.data);
                v.append_rows(&vc.data);
            }
            outs
        };

        let mut s_cold = KvPageStats::default();
        let cold = run(None, &mut s_cold);
        let mut cache = DecodedPageCache::new(1 << 20);
        let mut s_warm = KvPageStats::default();
        let warm = run(Some(&mut cache), &mut s_warm);

        assert_eq!(cold, warm, "cached prefill diverged from uncached");
        // Same page-visit counters; only the cache counters differ.
        assert_eq!(
            (s_cold.high_pages, s_cold.low_pages),
            (s_warm.high_pages, s_warm.low_pages)
        );
        assert_eq!(s_cold.total(), 10, "0+1+2+3+4 prefix K-page visits");
        assert_eq!((s_cold.cache_hits, s_cold.cache_misses), (0, 0));
        assert_eq!(s_warm.cache_misses, 8, "each distinct page decodes once per store");
        assert_eq!(s_warm.cache_hits, 12, "every revisit served from the cache");
        assert_eq!(s_warm.cache_evictions, 0);
    }

    #[test]
    fn cached_prefill_partial_frontier_page_bypasses_cache() {
        // Chunks of 4 with pt = 8: every other chunk leaves a half-full
        // frontier page, which must decode fresh (it grows in place) —
        // and still match the uncached kernel bit for bit.
        let (d, pt, lq, n_chunks) = (32usize, 8usize, 4usize, 6usize);
        let prompt_q = rows(n_chunks * lq, d, 130);
        let prompt_k = rows(n_chunks * lq, d, 131);
        let prompt_v = rows(n_chunks * lq, d, 132);
        let policy = KvPolicy { sink: 8, diag: 8 };

        let mut k = QuantPagedKv::new(d, KvFormat::Dual, pt);
        let mut v = QuantPagedKv::new(d, KvFormat::Dual, pt);
        let mut cache = DecodedPageCache::new(1 << 20);
        let (mut s_cold, mut s_warm) = (KvPageStats::default(), KvPageStats::default());
        for c in 0..n_chunks {
            let sl = |p: &[f32]| p[c * lq * d..(c + 1) * lq * d].to_vec();
            let q = Tensor::new(vec![lq, d], sl(&prompt_q));
            let kc = Tensor::new(vec![lq, d], sl(&prompt_k));
            let vc = Tensor::new(vec![lq, d], sl(&prompt_v));
            let cold = dma_attention_prefill_chunk(&q, &kc, &vc, &k, &v, &policy, &mut s_cold);
            let warm = dma_attention_prefill_chunk_cached(
                &q, &kc, &vc, &k, &v, &policy, &mut cache, &mut s_warm);
            assert_eq!(cold.data, warm.data, "chunk {c}");
            k.append_rows(&kc.data);
            v.append_rows(&vc.data);
        }
        // Odd chunks see a partial frontier page: visits outnumber
        // cache consultations, and revisited full pages do hit.
        assert!(s_warm.cache_hits > 0, "full prefix pages never reused");
        assert!(
            (s_warm.cache_hits + s_warm.cache_misses) < 2 * s_cold.total(),
            "partial frontier pages must bypass the cache"
        );
    }

    #[test]
    fn prefill_chunk_uses_position_aware_precision() {
        // The chunk's frontier is past the prefix, so a prefix page that
        // would be "frontier" for a decode at pos0-1 can fall out of the
        // diag window once the chunk is long enough.
        let (pos0, d, pt) = (32usize, 32usize, 8usize);
        let k = filled(pos0, d, KvFormat::Dual, pt, 60);
        let v = filled(pos0, d, KvFormat::Dual, pt, 61);
        let policy = KvPolicy { sink: 8, diag: 8 };
        let mk = |lq: usize, seed: u64| {
            (
                Tensor::new(vec![lq, d], rows(lq, d, seed)),
                Tensor::new(vec![lq, d], rows(lq, d, seed + 1)),
                Tensor::new(vec![lq, d], rows(lq, d, seed + 2)),
            )
        };
        // Short chunk (frontier 33): last prefix page overlaps the window.
        let (q, kc, vc) = mk(2, 70);
        let mut s_near = KvPageStats::default();
        dma_attention_prefill_chunk(&q, &kc, &vc, &k, &v, &policy, &mut s_near);
        assert_eq!(s_near, KvPageStats { high_pages: 2, low_pages: 2, ..Default::default() });
        // Long chunk (frontier 47): the window no longer reaches the
        // prefix at all — only the sink page decodes high.
        let (q, kc, vc) = mk(16, 80);
        let mut s_far = KvPageStats::default();
        dma_attention_prefill_chunk(&q, &kc, &vc, &k, &v, &policy, &mut s_far);
        assert_eq!(s_far, KvPageStats { high_pages: 1, low_pages: 3, ..Default::default() });
    }

    #[test]
    fn sink_and_diag_policy_improves_over_all_low() {
        // The paper's quality claim at page granularity, on
        // channel-structured keys where low-bit hurts.
        let d = 64;
        let n = 256;
        let pt = 16;
        let mut rng = Rng::new(12);
        let kx = crate::util::rng::channelwise_qk(&mut rng, n, d, 6, 8.0);
        let vx = rows(n, d, 13);
        let mut k = QuantPagedKv::new(d, KvFormat::Dual, pt);
        k.append_rows(&kx);
        let mut v = QuantPagedKv::new(d, KvFormat::Dual, pt);
        v.append_rows(&vx);

        let mut err = |sink: usize, diag: usize| -> f64 {
            let mut total = 0.0;
            for _ in 0..8 {
                let q = crate::util::rng::channelwise_qk(&mut rng, 1, d, 6, 8.0);
                let qq = dual_quant(&q, 1, d, true, Granularity::PerToken);
                let mut stats = KvPageStats::default();
                let out = dma_attention_paged(&qq, &k, &v, &KvPolicy { sink, diag }, &mut stats);
                // Exact f32 reference.
                let scale = 1.0 / (d as f32).sqrt();
                let mut s = vec![0f32; n];
                for (j, sv) in s.iter_mut().enumerate() {
                    *sv = kx[j * d..(j + 1) * d]
                        .iter()
                        .zip(&q)
                        .map(|(a, b)| a * b)
                        .sum::<f32>()
                        * scale;
                }
                let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let p: Vec<f32> = s.iter().map(|&x| (x - m).exp()).collect();
                let z: f32 = p.iter().sum();
                let mut reference = vec![0f32; d];
                for (j, &pj) in p.iter().enumerate() {
                    for c in 0..d {
                        reference[c] += pj / z * vx[j * d + c];
                    }
                }
                total += crate::metrics::rmse(&out.data, &reference);
            }
            total
        };
        let e_dma = err(32, 64);
        let e_low = err(0, 0);
        assert!(e_dma < e_low, "dma {e_dma} vs all-low {e_low}");
    }
}
