//! # DMA — Diagonal-Tiled Mixed-Precision Attention
//!
//! Rust coordinator for a full-system reproduction of *"Diagonal-Tiled
//! Mixed-Precision Attention for Efficient Low-Bit MXFP Inference"*
//! (Ding, Zhang, Guo; 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map:
//!
//! * **L1/L2 (build time)** — `python/compile/` authors the Pallas MXFP
//!   kernels and the JAX model, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — owns the request path: PJRT runtime
//!   ([`runtime`]), continuous batching and prefill/decode scheduling
//!   ([`coordinator`]), slotted/paged KV-cache management ([`kvcache`]),
//!   an MXFP-quantized paged KV cache with tile-precision-aware decode
//!   ([`kvquant`], [`attention::paged`]), a TCP JSON-lines server
//!   ([`server`]).
//!
//! The paper's numerics are mirrored bit-exactly in Rust ([`mxfp`],
//! [`attention`]) so every table and figure of the evaluation can be
//! regenerated without a GPU ([`perfmodel`] projects measured structure
//! onto B200 throughput; see DESIGN.md §4 for the substitution map).
//!
//! The serving cache has two storage backends, selected by
//! `EngineConfig::kv_format`: the full-precision batch slots the bucketed
//! PJRT executables require, and the quantized paged store ([`kvquant`])
//! that keeps K/V in MXFP8/NVFP4 pages end to end — cutting cache bytes
//! ~3–6x and decoding each page at the precision the paper's
//! diagonal-tile policy assigns (sink + causal frontier high, body low).

pub mod attention;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod kvquant;
pub mod metrics;
pub mod model;
pub mod mxfp;
pub mod perfmodel;
pub mod runtime;
pub mod server;
pub mod simd;
pub mod spec;
pub mod telemetry;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
