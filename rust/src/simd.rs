//! Vectorized inner kernels for the attention hot path, behind the
//! `simd` cargo feature.
//!
//! Every function here has one canonical definition — the scalar code in
//! [`scalar`] — and an optional `core::arch` implementation (SSE2 on
//! x86_64, NEON on aarch64; both are baseline features of their targets,
//! so there is no runtime dispatch). The public functions select the
//! widest available implementation at compile time; any other
//! arch/feature combination silently falls back to scalar, so the crate
//! builds everywhere.
//!
//! **Bit-exactness contract.** The vector paths must produce the same
//! f32 bits as the scalar paths on every input:
//!
//! * [`dot_blocked`] keeps the 4-chain reassociation explicit: vector
//!   lane `i` accumulates exactly the scalar chain `acc[i]` (same
//!   multiplies, same adds, same order), and the horizontal reduction is
//!   the scalar `(l0 + l1) + (l2 + l3)` — never a tree the compiler
//!   picks.
//! * Everything else ([`scale_in_place`], [`axpy`], [`lut_mul_scale`],
//!   [`nibble_lut_mul_scale`]) is elementwise: per element one IEEE
//!   multiply (and one add), identically rounded in scalar and vector
//!   form. FMA is never used — a fused multiply-add rounds once where
//!   mul-then-add rounds twice, which would change bits.
//!
//! The unit tests here compare the dispatch against [`scalar`] on
//! random shapes (including ragged tails); the cross-language goldens
//! (`testdata/golden_mxfp.json`, `testdata/golden_kvquant.json`) cover
//! the same paths end to end because [`crate::mxfp::fused`] and
//! [`crate::attention`] route their inner loops through this module —
//! CI runs the full test suite with the feature both off and on.

/// Canonical scalar kernels — the bit-exactness reference. Public so
/// tests and `benches/table12_decode_hotpath.rs` can time and compare
/// the dispatch against them even when the `simd` feature is on.
pub mod scalar {
    /// Dot product blocked into four independent accumulator chains so
    /// the adds pipeline instead of serializing on one dependency chain
    /// (f32 reassociation is deterministic — the same blocking always
    /// produces the same bits).
    #[inline]
    pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n - n % 4;
        let mut acc = [0f32; 4];
        let mut i = 0;
        while i < n4 {
            acc[0] += a[i] * b[i];
            acc[1] += a[i + 1] * b[i + 1];
            acc[2] += a[i + 2] * b[i + 2];
            acc[3] += a[i + 3] * b[i + 3];
            i += 4;
        }
        let mut tail = 0f32;
        for j in n4..n {
            tail += a[j] * b[j];
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
    }

    /// `x[i] *= alpha` (OnlineSoftmax accumulator rescale).
    #[inline]
    pub fn scale_in_place(x: &mut [f32], alpha: f32) {
        for v in x.iter_mut() {
            *v *= alpha;
        }
    }

    /// `acc[i] += p * v[i]` (OnlineSoftmax probability-weighted V row).
    #[inline]
    pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        for (a, &vv) in acc.iter_mut().zip(v) {
            *a += p * vv;
        }
    }

    /// `out[i] = lut[codes[i]] * s` (MXFP8 E4M3 row decode, one block).
    #[inline]
    pub fn lut_mul_scale(out: &mut [f32], codes: &[u8], lut: &[f32; 256], s: f32) {
        debug_assert_eq!(out.len(), codes.len());
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = lut[c as usize] * s;
        }
    }

    /// Packed-nibble gather-decode: `out[2i] = lut[packed[i] & 0xF] * s`,
    /// `out[2i+1] = lut[packed[i] >> 4] * s` (NVFP4 E2M1 row decode; the
    /// pack convention is `mxfp::pack` — low nibble = even element).
    #[inline]
    pub fn nibble_lut_mul_scale(out: &mut [f32], packed: &[u8], lut: &[f32; 16], s: f32) {
        debug_assert_eq!(out.len(), packed.len() * 2);
        for (o, &byte) in out.chunks_exact_mut(2).zip(packed) {
            o[0] = lut[(byte & 0x0F) as usize] * s;
            o[1] = lut[(byte >> 4) as usize] * s;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    //! SSE2 implementations (baseline on x86_64 — no runtime detection).
    //! Mul and add stay separate instructions; see the module contract.
    use core::arch::x86_64::*;

    #[inline]
    pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n - n % 4;
        let mut lanes = [0f32; 4];
        // SAFETY: all loads/stores are within the n4-bounded prefix of
        // the slices (unaligned ops, no alignment requirement).
        unsafe {
            let mut acc = _mm_setzero_ps();
            let mut i = 0;
            while i < n4 {
                let av = _mm_loadu_ps(a.as_ptr().add(i));
                let bv = _mm_loadu_ps(b.as_ptr().add(i));
                acc = _mm_add_ps(acc, _mm_mul_ps(av, bv));
                i += 4;
            }
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        let mut tail = 0f32;
        for j in n4..n {
            tail += a[j] * b[j];
        }
        (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
    }

    #[inline]
    pub fn scale_in_place(x: &mut [f32], alpha: f32) {
        let n = x.len();
        let n4 = n - n % 4;
        // SAFETY: in-place unaligned load/store pairs within [0, n4).
        unsafe {
            let al = _mm_set1_ps(alpha);
            let mut i = 0;
            while i < n4 {
                let p = x.as_mut_ptr().add(i);
                _mm_storeu_ps(p, _mm_mul_ps(_mm_loadu_ps(p), al));
                i += 4;
            }
        }
        for v in &mut x[n4..] {
            *v *= alpha;
        }
    }

    #[inline]
    pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        let n = acc.len();
        let n4 = n - n % 4;
        // SAFETY: unaligned ops within the n4-bounded prefix; `acc` and
        // `v` are distinct slices (&mut vs &).
        unsafe {
            let pv = _mm_set1_ps(p);
            let mut i = 0;
            while i < n4 {
                let ap = acc.as_mut_ptr().add(i);
                let vv = _mm_loadu_ps(v.as_ptr().add(i));
                _mm_storeu_ps(ap, _mm_add_ps(_mm_loadu_ps(ap), _mm_mul_ps(pv, vv)));
                i += 4;
            }
        }
        for (a, &vv) in acc[n4..].iter_mut().zip(&v[n4..]) {
            *a += p * vv;
        }
    }

    #[inline]
    pub fn lut_mul_scale(out: &mut [f32], codes: &[u8], lut: &[f32; 256], s: f32) {
        debug_assert_eq!(out.len(), codes.len());
        let n = out.len();
        let n4 = n - n % 4;
        // SAFETY: stores within [0, n4); gathers are safe indexing (SSE2
        // has no gather — the vector win is the 4-wide scale multiply
        // and single store).
        unsafe {
            let sv = _mm_set1_ps(s);
            let mut i = 0;
            while i < n4 {
                let g = _mm_set_ps(
                    lut[codes[i + 3] as usize],
                    lut[codes[i + 2] as usize],
                    lut[codes[i + 1] as usize],
                    lut[codes[i] as usize],
                );
                _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(g, sv));
                i += 4;
            }
        }
        for (o, &c) in out[n4..].iter_mut().zip(&codes[n4..]) {
            *o = lut[c as usize] * s;
        }
    }

    #[inline]
    pub fn nibble_lut_mul_scale(out: &mut [f32], packed: &[u8], lut: &[f32; 16], s: f32) {
        debug_assert_eq!(out.len(), packed.len() * 2);
        let nb = packed.len();
        let nb2 = nb - nb % 2; // two packed bytes -> one 4-lane vector
        // SAFETY: each store writes out[2b..2b+4] with 2b + 4 <= 2*nb2.
        unsafe {
            let sv = _mm_set1_ps(s);
            let mut b = 0;
            while b < nb2 {
                let (b0, b1) = (packed[b], packed[b + 1]);
                let g = _mm_set_ps(
                    lut[(b1 >> 4) as usize],
                    lut[(b1 & 0x0F) as usize],
                    lut[(b0 >> 4) as usize],
                    lut[(b0 & 0x0F) as usize],
                );
                _mm_storeu_ps(out.as_mut_ptr().add(2 * b), _mm_mul_ps(g, sv));
                b += 2;
            }
        }
        for (o, &byte) in out[2 * nb2..].chunks_exact_mut(2).zip(&packed[nb2..]) {
            o[0] = lut[(byte & 0x0F) as usize] * s;
            o[1] = lut[(byte >> 4) as usize] * s;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    //! NEON implementations (baseline on aarch64). `vmulq`/`vaddq` only —
    //! no `vfmaq`, which would fuse the rounding and change bits.
    use core::arch::aarch64::*;

    #[inline]
    pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n - n % 4;
        let mut lanes = [0f32; 4];
        // SAFETY: loads/stores within the n4-bounded prefix.
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            let mut i = 0;
            while i < n4 {
                let av = vld1q_f32(a.as_ptr().add(i));
                let bv = vld1q_f32(b.as_ptr().add(i));
                acc = vaddq_f32(acc, vmulq_f32(av, bv));
                i += 4;
            }
            vst1q_f32(lanes.as_mut_ptr(), acc);
        }
        let mut tail = 0f32;
        for j in n4..n {
            tail += a[j] * b[j];
        }
        (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
    }

    #[inline]
    pub fn scale_in_place(x: &mut [f32], alpha: f32) {
        let n = x.len();
        let n4 = n - n % 4;
        // SAFETY: in-place load/store pairs within [0, n4).
        unsafe {
            let al = vdupq_n_f32(alpha);
            let mut i = 0;
            while i < n4 {
                let p = x.as_mut_ptr().add(i);
                vst1q_f32(p, vmulq_f32(vld1q_f32(p), al));
                i += 4;
            }
        }
        for v in &mut x[n4..] {
            *v *= alpha;
        }
    }

    #[inline]
    pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        let n = acc.len();
        let n4 = n - n % 4;
        // SAFETY: ops within the n4-bounded prefix; distinct slices.
        unsafe {
            let pv = vdupq_n_f32(p);
            let mut i = 0;
            while i < n4 {
                let ap = acc.as_mut_ptr().add(i);
                let vv = vld1q_f32(v.as_ptr().add(i));
                vst1q_f32(ap, vaddq_f32(vld1q_f32(ap), vmulq_f32(pv, vv)));
                i += 4;
            }
        }
        for (a, &vv) in acc[n4..].iter_mut().zip(&v[n4..]) {
            *a += p * vv;
        }
    }

    #[inline]
    pub fn lut_mul_scale(out: &mut [f32], codes: &[u8], lut: &[f32; 256], s: f32) {
        debug_assert_eq!(out.len(), codes.len());
        let n = out.len();
        let n4 = n - n % 4;
        // SAFETY: stores within [0, n4); gathers are safe indexing.
        unsafe {
            let sv = vdupq_n_f32(s);
            let mut i = 0;
            while i < n4 {
                let g = [
                    lut[codes[i] as usize],
                    lut[codes[i + 1] as usize],
                    lut[codes[i + 2] as usize],
                    lut[codes[i + 3] as usize],
                ];
                let gv = vld1q_f32(g.as_ptr());
                vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(gv, sv));
                i += 4;
            }
        }
        for (o, &c) in out[n4..].iter_mut().zip(&codes[n4..]) {
            *o = lut[c as usize] * s;
        }
    }

    #[inline]
    pub fn nibble_lut_mul_scale(out: &mut [f32], packed: &[u8], lut: &[f32; 16], s: f32) {
        debug_assert_eq!(out.len(), packed.len() * 2);
        let nb = packed.len();
        let nb2 = nb - nb % 2;
        // SAFETY: each store writes out[2b..2b+4] with 2b + 4 <= 2*nb2.
        unsafe {
            let sv = vdupq_n_f32(s);
            let mut b = 0;
            while b < nb2 {
                let (b0, b1) = (packed[b], packed[b + 1]);
                let g = [
                    lut[(b0 & 0x0F) as usize],
                    lut[(b0 >> 4) as usize],
                    lut[(b1 & 0x0F) as usize],
                    lut[(b1 >> 4) as usize],
                ];
                let gv = vld1q_f32(g.as_ptr());
                vst1q_f32(out.as_mut_ptr().add(2 * b), vmulq_f32(gv, sv));
                b += 2;
            }
        }
        for (o, &byte) in out[2 * nb2..].chunks_exact_mut(2).zip(&packed[nb2..]) {
            o[0] = lut[(byte & 0x0F) as usize] * s;
            o[1] = lut[(byte >> 4) as usize] * s;
        }
    }
}

// Compile-time dispatch: exactly one arm of each function body survives
// cfg evaluation, so there is no runtime branch and no dead code.

/// Blocked dot product (see [`scalar::dot_blocked`] for the canonical
/// reassociation). Shared by every score kernel in [`crate::attention`].
#[inline]
pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        x86::dot_blocked(a, b)
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        neon::dot_blocked(a, b)
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        scalar::dot_blocked(a, b)
    }
}

/// `x[i] *= alpha` (OnlineSoftmax accumulator rescale).
#[inline]
pub fn scale_in_place(x: &mut [f32], alpha: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        x86::scale_in_place(x, alpha)
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        neon::scale_in_place(x, alpha)
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        scalar::scale_in_place(x, alpha)
    }
}

/// `acc[i] += p * v[i]` (OnlineSoftmax probability-weighted V row).
#[inline]
pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        x86::axpy(acc, p, v)
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        neon::axpy(acc, p, v)
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        scalar::axpy(acc, p, v)
    }
}

/// `out[i] = lut[codes[i]] * s` (MXFP8 E4M3 block decode).
#[inline]
pub fn lut_mul_scale(out: &mut [f32], codes: &[u8], lut: &[f32; 256], s: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        x86::lut_mul_scale(out, codes, lut, s)
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        neon::lut_mul_scale(out, codes, lut, s)
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        scalar::lut_mul_scale(out, codes, lut, s)
    }
}

/// Packed-nibble gather-decode (NVFP4 E2M1 block decode).
#[inline]
pub fn nibble_lut_mul_scale(out: &mut [f32], packed: &[u8], lut: &[f32; 16], s: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        x86::nibble_lut_mul_scale(out, packed, lut, s)
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        neon::nibble_lut_mul_scale(out, packed, lut, s)
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        scalar::nibble_lut_mul_scale(out, packed, lut, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randf(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 2.0).collect()
    }

    fn randb(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
    }

    // Ragged lengths exercise both the vector body and the scalar tail.
    const LENS: [usize; 6] = [0, 3, 4, 31, 32, 61];

    #[test]
    fn dot_blocked_bit_matches_scalar() {
        for (i, &n) in LENS.iter().enumerate() {
            let a = randf(n, 100 + i as u64);
            let b = randf(n, 200 + i as u64);
            assert_eq!(
                dot_blocked(&a, &b).to_bits(),
                scalar::dot_blocked(&a, &b).to_bits(),
                "n={n}"
            );
        }
    }

    #[test]
    fn scale_in_place_bit_matches_scalar() {
        for (i, &n) in LENS.iter().enumerate() {
            for alpha in [0.0f32, 1.0, 0.37, -2.5e-3] {
                let mut x = randf(n, 300 + i as u64);
                let mut y = x.clone();
                scale_in_place(&mut x, alpha);
                scalar::scale_in_place(&mut y, alpha);
                assert_eq!(bits(&x), bits(&y), "n={n} alpha={alpha}");
            }
        }
    }

    #[test]
    fn axpy_bit_matches_scalar() {
        for (i, &n) in LENS.iter().enumerate() {
            let v = randf(n, 400 + i as u64);
            let mut a = randf(n, 500 + i as u64);
            let mut b = a.clone();
            axpy(&mut a, 0.73, &v);
            scalar::axpy(&mut b, 0.73, &v);
            assert_eq!(bits(&a), bits(&b), "n={n}");
        }
    }

    #[test]
    fn lut_decoders_bit_match_scalar() {
        let lut8 = crate::mxfp::fp8::e4m3_table();
        let lut4 = &crate::mxfp::e2m1::DECODE_LUT;
        for (i, &n) in LENS.iter().enumerate() {
            let codes = randb(n, 600 + i as u64);
            let mut a = vec![0f32; n];
            let mut b = vec![0f32; n];
            lut_mul_scale(&mut a, &codes, lut8, 0.031);
            scalar::lut_mul_scale(&mut b, &codes, lut8, 0.031);
            assert_eq!(bits(&a), bits(&b), "lut8 n={n}");

            let packed = randb(n, 700 + i as u64);
            let mut a = vec![0f32; 2 * n];
            let mut b = vec![0f32; 2 * n];
            nibble_lut_mul_scale(&mut a, &packed, lut4, 1.7);
            scalar::nibble_lut_mul_scale(&mut b, &packed, lut4, 1.7);
            assert_eq!(bits(&a), bits(&b), "lut4 n={n}");
        }
    }

    fn bits(x: &[f32]) -> Vec<u32> {
        x.iter().map(|v| v.to_bits()).collect()
    }
}
