//! Minimal row-major f32 tensor used across the Rust-side numerics.
//!
//! Deliberately small: the heavy math on the request path runs inside
//! PJRT executables; this type serves the CPU mirrors (attention
//! oracles, quantization pipelines, eval harness) and host-side
//! batch assembly.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: (0..n).map(&mut f).collect() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    /// Row view of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols() + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cc = self.cols();
        self.data[r * cc + c] = v;
    }

    /// `self [m,k] @ other [k,n] -> [m,n]` (ikj loop order, no alloc in
    /// the inner loop).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// `self [m,k] @ other^T [n,k] -> [m,n]` — the attention-score shape.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul_t dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let b_row = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (a, b) in a_row.iter().zip(b_row) {
                    acc += a * b;
                }
                out[i * n + j] = acc;
            }
        }
        Tensor::new(vec![m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::new(self.shape.clone(), self.data.iter().map(|v| v * s).collect())
    }

    /// Row-wise softmax of a 2-D tensor (handles -inf rows of masks).
    pub fn softmax_rows(&self) -> Tensor {
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &v) in orow.iter_mut().zip(row) {
                let e = (v - mx).exp();
                *o = e;
                sum += e;
            }
            if sum > 0.0 {
                for o in orow.iter_mut() {
                    *o /= sum;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    pub fn slice_rows(&self, start: usize, end: usize) -> Tensor {
        let c = self.cols();
        Tensor::new(vec![end - start, c], self.data[start * c..end * c].to_vec())
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }
}

/// Random normal tensor from the given seed.
pub fn randn(shape: Vec<usize>, seed: u64) -> Tensor {
    let mut rng = crate::util::rng::Rng::new(seed);
    let n = shape.iter().product();
    Tensor::new(shape, rng.normal_vec(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_t_equals_matmul_of_transpose() {
        let a = randn(vec![5, 8], 1);
        let b = randn(vec![7, 8], 2);
        let c1 = a.matmul_t(&b);
        let c2 = a.matmul(&b.transpose2());
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = randn(vec![4, 9], 3);
        let p = t.softmax_rows();
        for i in 0..4 {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_handles_masked_row_tail() {
        let t = Tensor::new(vec![1, 3], vec![0.0, f32::NEG_INFINITY, 0.0]);
        let p = t.softmax_rows();
        assert!((p.data[0] - 0.5).abs() < 1e-6);
        assert_eq!(p.data[1], 0.0);
    }

    #[test]
    fn transpose_round_trip() {
        let t = randn(vec![3, 5], 4);
        assert_eq!(t.transpose2().transpose2(), t);
    }

    #[test]
    fn slice_rows_contents() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
