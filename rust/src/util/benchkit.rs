//! Micro-benchmark harness (criterion is not vendored).
//!
//! Warmup + repeated timed runs with mean / median / p10 / p90, table
//! printing in the paper's row format, and CSV dumping under
//! `bench_out/`. Every `cargo bench` target uses this.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Stats {
        n: samples.len(),
        mean_ns: mean,
        median_ns: pct(0.5),
        p10_ns: pct(0.1),
        p90_ns: pct(0.9),
        min_ns: samples[0],
    }
}

/// The paper's measurement protocol (Sec. 6.4): 5 warmups, mean of 10.
pub fn bench_paper_protocol<F: FnMut()>(f: F) -> Stats {
    bench(5, 10, f)
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s += &format!("{:<w$} | ", c, w = widths[i]);
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Write as JSON rows under `bench_out/<name>.json` (creates the
    /// directory): one object per row, keyed by the column headers.
    pub fn write_json(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        use crate::util::json::Json;
        let dir = std::path::Path::new("bench_out");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.json"));
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                Json::obj(
                    self.headers
                        .iter()
                        .zip(row)
                        .map(|(h, c)| (h.as_str(), Json::str(c.clone())))
                        .collect(),
                )
            })
            .collect();
        std::fs::write(&path, Json::arr(rows).to_string())?;
        Ok(path)
    }

    /// Write as CSV under `bench_out/<name>.csv` (creates the directory).
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_out");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut out = String::new();
        out += &self.headers.join(",");
        out += "\n";
        for row in &self.rows {
            out += &row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect::<Vec<_>>()
                .join(",");
            out += "\n";
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 20);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.min_ns <= s.p10_ns);
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn table_rows_must_match_headers() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["x"]);
        t.row(&["a,b\"c".into()]);
        // Write to a temp cwd-independent check of the escaping logic only.
        let cell = "a,b\"c";
        let escaped = format!("\"{}\"", cell.replace('"', "\"\""));
        assert_eq!(escaped, "\"a,b\"\"c\"");
    }
}
