//! Deterministic PRNG + distributions (rand/rand_distr are not vendored).
//!
//! SplitMix64 for seeding, xoshiro256++ as the main generator, Box-Muller
//! for normals. Deterministic across platforms — benches and property
//! tests rely on reproducible streams.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Q/K-like activations with channel-structured outliers.
///
/// The paper (Sec. 4, Fig. 1) observes that quantization error in LLM
/// query/key matrices has a pronounced *channel-wise* structure: a few
/// feature dimensions carry consistently larger magnitudes. This
/// generator reproduces that structure synthetically: base N(0,1)
/// activations with `n_outlier` channels scaled by `outlier_scale` and a
/// smooth per-channel modulation.
pub fn channelwise_qk(
    rng: &mut Rng,
    rows: usize,
    d: usize,
    n_outlier: usize,
    outlier_scale: f32,
) -> Vec<f32> {
    let mut chan_scale = vec![1.0f32; d];
    for c in 0..d {
        // Smooth modulation in [0.5, 1.5].
        chan_scale[c] = 1.0 + 0.5 * (c as f32 * 0.37).sin();
    }
    let mut idx: Vec<usize> = (0..d).collect();
    rng.shuffle(&mut idx);
    for &c in idx.iter().take(n_outlier) {
        chan_scale[c] *= outlier_scale;
    }
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        for c in 0..d {
            out[r * d + c] = rng.normal() as f32 * chan_scale[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn int_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.int_in(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn channelwise_outliers_present() {
        let mut r = Rng::new(5);
        let d = 64;
        let x = channelwise_qk(&mut r, 256, d, 4, 10.0);
        // Per-channel RMS must have a heavy tail.
        let mut rms = vec![0.0f64; d];
        for row in 0..256 {
            for c in 0..d {
                rms[c] += (x[row * d + c] as f64).powi(2);
            }
        }
        let mut rms: Vec<f64> = rms.iter().map(|v| (v / 256.0).sqrt()).collect();
        rms.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(rms[0] > 4.0 * rms[8], "no outlier channels: {:?}", &rms[..6]);
    }
}
