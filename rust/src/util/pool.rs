//! Persistent worker pool for the decode/prefill hot paths.
//!
//! [`par_items`] offers the exact contract of
//! [`crate::util::par::par_items`] — disjoint owned items, bit-identical
//! results at any thread count, inline fallback for `threads <= 1` — but
//! feeds a lazily-initialized process-global pool over a channel instead
//! of paying `threads - 1` OS thread spawns per call. The scoped version
//! spawns once per layer per token on the decode path; the pool spawns
//! once per process and amortizes to a channel send plus a condvar wait.
//!
//! The pool grows on demand to the largest `threads - 1` any caller has
//! requested and never shrinks; engine restarts in one process reuse the
//! same workers ([`worker_count`] exposes the size for the no-leak
//! test). Workers never unwind: each job runs under `catch_unwind`, and
//! a panic in any chunk is re-raised on the submitting thread after all
//! of the call's chunks have finished, so stack-borrowed work items are
//! never touched past the submitter's frame.
//!
//! Queueing is observable: every job records enqueue-to-dequeue latency
//! into [`wait_histogram`], exported as `dma_pool_wait_seconds` by
//! [`crate::telemetry::render_prometheus`].

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::telemetry::Histogram;

/// Enqueue-to-dequeue wall time of pool jobs, in integer microseconds.
/// Zero-alloc to record (three relaxed atomic adds), so it stays on even
/// in benches; the process-global pool means one process-global family.
pub fn wait_histogram() -> &'static Histogram {
    static H: OnceLock<Histogram> = OnceLock::new();
    H.get_or_init(Histogram::new)
}

/// Number of live pool workers (0 until the first fan-out). The pool
/// only grows when a call asks for more concurrency than any call
/// before it; repeated fan-outs and engine restarts reuse workers.
pub fn worker_count() -> usize {
    pool().spawned.load(Ordering::Acquire)
}

/// Type-erased unit of work: a raw context pointer plus the monomorphic
/// runner that knows its real type. The submitter keeps the context
/// alive on its stack until the latch confirms every job has finished,
/// which is what makes the erased pointer sound.
struct Job {
    data: *mut (),
    run: unsafe fn(*mut ()),
    submitted: Instant,
}

// SAFETY: `data` points into the submitting thread's stack frame, which
// outlives the job (the submitter blocks on the latch before returning),
// and the pointed-to context only exposes `Send` items and a `Sync`
// closure to the runner.
unsafe impl Send for Job {}

/// Completion latch for one `par_items` call: counts outstanding jobs
/// and carries the sticky panic flag back to the submitter.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch {
            remaining: Mutex::new(jobs),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn arrive(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.done.wait(g).unwrap();
        }
    }
}

/// One chunk of a fan-out, in the form the erased runner reconstructs:
/// raw slice parts, the shared closure, and the call's latch.
struct ChunkCtx<T, F> {
    ptr: *mut T,
    len: usize,
    f: *const F,
    latch: *const Latch,
}

/// Monomorphic runner behind `Job::run`. Catches panics so the worker
/// thread survives, then arrives at the latch unconditionally — the
/// submitter must never deadlock on a panicked chunk.
///
/// SAFETY: caller (the worker loop) must pass a `data` obtained from
/// `par_items`'s `ChunkCtx<T, F>` for these exact `T`, `F`, and only
/// while the submitting call is still blocked on its latch.
unsafe fn run_chunk<T, F: Fn(&mut T) + Sync>(data: *mut ()) {
    let ctx = &*(data as *const ChunkCtx<T, F>);
    let latch = &*ctx.latch;
    let res = catch_unwind(AssertUnwindSafe(|| {
        let items = std::slice::from_raw_parts_mut(ctx.ptr, ctx.len);
        let f = &*ctx.f;
        for it in items {
            f(it);
        }
    }));
    if res.is_err() {
        latch.panicked.store(true, Ordering::Release);
    }
    latch.arrive();
}

struct Pool {
    /// Guarded sender: `mpsc::Sender` is not `Sync` on older toolchains,
    /// and a fan-out sends all its jobs in one short critical section.
    tx: Mutex<Sender<Job>>,
    /// Shared dequeue end; contention is fine because jobs are coarse
    /// (a per-kv-head or per-sequence attention chunk, not a row).
    rx: Arc<Mutex<Receiver<Job>>>,
    spawned: AtomicUsize,
    grow: Mutex<()>,
}

fn pool() -> &'static Pool {
    static P: OnceLock<Pool> = OnceLock::new();
    P.get_or_init(|| {
        let (tx, rx) = channel::<Job>();
        Pool {
            tx: Mutex::new(tx),
            rx: Arc::new(Mutex::new(rx)),
            spawned: AtomicUsize::new(0),
            grow: Mutex::new(()),
        }
    })
}

impl Pool {
    fn ensure_workers(&self, n: usize) {
        if self.spawned.load(Ordering::Acquire) >= n {
            return;
        }
        let _g = self.grow.lock().unwrap();
        let cur = self.spawned.load(Ordering::Acquire);
        for _ in cur..n {
            let rx = Arc::clone(&self.rx);
            std::thread::Builder::new()
                .name("dma-pool-worker".into())
                .spawn(move || worker_loop(rx))
                .expect("spawn pool worker");
        }
        if n > cur {
            self.spawned.store(n, Ordering::Release);
        }
    }

    fn submit(&self, jobs: impl Iterator<Item = Job>) {
        let tx = self.tx.lock().unwrap();
        for job in jobs {
            tx.send(job).expect("pool receiver lives for the process");
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let g = match rx.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            g.recv()
        };
        let job = match job {
            Ok(j) => j,
            Err(_) => return,
        };
        wait_histogram().record_us(job.submitted.elapsed().as_micros() as u64);
        // SAFETY: jobs come only from `par_items`, whose submitter is
        // still blocked on the latch, so the context is alive and typed
        // for this runner.
        unsafe { (job.run)(job.data) };
    }
}

/// Apply `f` to every item, fanning the slice across up to `threads`
/// workers of the process-global pool. Same contract as
/// [`crate::util::par::par_items`]: items are processed exactly once,
/// partitioning is balanced and depends only on `items.len()` and
/// `threads`, and each item owns its outputs — so results are
/// bit-identical at any thread count. `threads <= 1` (or a single item)
/// runs inline without touching the pool.
///
/// The calling thread works the first chunk itself while pool workers
/// drain the rest; a panic in any chunk resumes on the calling thread
/// after all chunks finish, and the workers survive for reuse.
pub fn par_items<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], threads: usize, f: F) {
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }

    let pool = pool();
    pool.ensure_workers(threads - 1);

    let latch = Latch::new(threads - 1);
    let mut chunks = super::par::balanced_chunks(items, threads).into_iter();
    let own = chunks.next().expect("threads >= 2 implies a first chunk");
    // Contexts live on this frame; the latch wait below keeps them (and
    // `f`, and the chunks' borrows) alive until every job is done.
    let ctxs: Vec<ChunkCtx<T, F>> = chunks
        .map(|c| ChunkCtx {
            ptr: c.as_mut_ptr(),
            len: c.len(),
            f: &f,
            latch: &latch,
        })
        .collect();
    pool.submit(ctxs.iter().map(|ctx| Job {
        data: ctx as *const ChunkCtx<T, F> as *mut (),
        run: run_chunk::<T, F>,
        submitted: Instant::now(),
    }));

    // Work the first chunk inline. Catch — don't propagate yet — so the
    // latch wait always runs and workers never outlive the contexts.
    let own_res = catch_unwind(AssertUnwindSafe(|| {
        for it in own {
            f(it);
        }
    }));
    latch.wait();

    if let Err(e) = own_res {
        resume_unwind(e);
    }
    if latch.panicked.load(Ordering::Acquire) {
        panic!("util::pool::par_items: a pooled chunk panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_items_processed_once_any_thread_count() {
        for threads in [0usize, 1, 2, 3, 7, 64] {
            let mut items: Vec<(usize, u64)> = (0..13).map(|i| (i, 0u64)).collect();
            par_items(&mut items, threads, |it| {
                it.1 += (it.0 as u64 + 1) * 10;
            });
            for (i, got) in items {
                assert_eq!(got, (i as u64 + 1) * 10, "threads {threads} item {i}");
            }
        }
    }

    #[test]
    fn disjoint_mut_slices_match_serial_bit_for_bit() {
        let serial = {
            let mut b = vec![0f32; 24];
            for (i, chunk) in b.chunks_mut(6).enumerate() {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 100 + j) as f32;
                }
            }
            b
        };
        for threads in [1usize, 2, 4, 8] {
            let mut buf = vec![0f32; 24];
            let mut items: Vec<(usize, &mut [f32])> =
                buf.chunks_mut(6).enumerate().collect();
            par_items(&mut items, threads, |(i, chunk)| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (*i * 100 + j) as f32;
                }
            });
            assert_eq!(buf, serial, "threads {threads}");
        }
    }

    #[test]
    fn empty_slice_is_a_noop() {
        let mut items: Vec<u32> = Vec::new();
        par_items(&mut items, 8, |_| panic!("no items to visit"));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let mut items: Vec<u32> = (0..8).collect();
        let hit = catch_unwind(AssertUnwindSafe(|| {
            par_items(&mut items, 4, |it| {
                if *it == 6 {
                    panic!("boom in pooled chunk");
                }
            });
        }));
        assert!(hit.is_err(), "panic in a pooled chunk must propagate");
        // The pool is still serviceable after a panicked round.
        let mut items: Vec<u64> = vec![0; 9];
        par_items(&mut items, 4, |it| *it += 1);
        assert!(items.iter().all(|&v| v == 1));
    }

    #[test]
    fn repeated_fanouts_do_not_leak_workers() {
        let mut items = vec![0u64; 16];
        par_items(&mut items, 4, |it| *it += 1); // warm to this test's size
        let before = worker_count();
        assert!(before >= 3, "pool should hold at least threads-1 workers");
        for _ in 0..100 {
            par_items(&mut items, 4, |it| *it += 1);
        }
        // Reuse, not respawn: growth is bounded by the largest request
        // (other tests share the process-global pool), never per-call.
        let after = worker_count();
        assert!(
            after <= before.max(63),
            "pool grew per-call: {before} -> {after}"
        );
        assert_eq!(items.iter().sum::<u64>(), 16 * 101);
    }

    #[test]
    fn wait_histogram_records_queue_time() {
        let n0 = wait_histogram().snapshot().count;
        let mut items = vec![0u64; 8];
        par_items(&mut items, 4, |it| *it += 1);
        // 3 jobs were queued; the submitter's inline chunk never queues.
        assert!(wait_histogram().snapshot().count >= n0 + 3);
    }
}
