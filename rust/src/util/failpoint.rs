//! Deterministic fault injection for resilience testing.
//!
//! A *failpoint* is a named site in the serving path (`pool_admission`,
//! `decode_step`, `prefill_chunk`, `decode_multi`, `writer_queue`) that
//! can be armed at process start to inject a fault on a reproducible
//! schedule: `--fault "site:kind:prob[:delay_ms]"` on the CLI or the
//! `DMA_FAULTS` env var (comma-separated specs; `DMA_FAULT_SEED` seeds
//! the schedule). Kinds:
//!
//! - `panic` — panic in place, killing the engine worker thread (the
//!   router's supervisor detects the closed event channel and respawns).
//! - `error` — return an `Err` from the site, which propagates out of
//!   `Engine::step` and stops the worker loop (same recovery path).
//! - `delay` — sleep `delay_ms` (default 10) to simulate a wedged
//!   backend or slow I/O without killing anything.
//!
//! The schedule is deterministic: hit `i` of site `s` fires iff
//! `mix(seed, fnv(s), i) < prob`, so a given `(spec, seed)` pair
//! reproduces the exact same fault sequence run after run — chaos tests
//! shrink to a seed, not to a flaky trace.
//!
//! Cost when disarmed: [`check`] is one `Relaxed` atomic load and an
//! immediate return — no allocation, no lock, no branch on site name.
//! `table16_resilience` asserts the zero-allocation claim with a
//! counting global allocator.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, RwLock};

/// What an armed site injects when its schedule fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic in place (simulates a crashing worker).
    Panic,
    /// Return an error from the site (simulates a failing backend call).
    Error,
    /// Sleep `delay_ms` (simulates a wedged dependency).
    Delay,
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind, String> {
        match s {
            "panic" => Ok(FaultKind::Panic),
            "error" => Ok(FaultKind::Error),
            "delay" => Ok(FaultKind::Delay),
            other => Err(format!("unknown fault kind '{other}' (panic|error|delay)")),
        }
    }
}

struct Site {
    name: String,
    name_hash: u64,
    kind: FaultKind,
    /// Probability in [0, 1] that a given hit fires.
    prob: f64,
    delay_ms: u64,
    /// Times the site was reached (schedule index).
    hits: AtomicU64,
    /// Times the site actually injected a fault.
    fired: AtomicU64,
}

/// Fast-path gate: a single `Relaxed` load decides "disarmed" without
/// touching the registry lock.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: RwLock<Vec<Site>> = RwLock::new(Vec::new());
/// Serializes tests that arm the global registry (see [`exclusive`]).
static EXCLUSIVE: Mutex<()> = Mutex::new(());

/// FNV-1a over the site name; folded into the schedule so distinct
/// sites see decorrelated streams under one seed.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64-style finalizer mapping (seed, site, hit) to a uniform
/// in [0, 1) — the deterministic schedule.
fn schedule_uniform(seed: u64, name_hash: u64, hit: u64) -> f64 {
    let mut z = seed
        ^ name_hash.rotate_left(17)
        ^ hit.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Arm the registry from a comma-separated spec string
/// (`site:kind:prob[:delay_ms]`), replacing any previous configuration.
/// An empty spec disarms. Errors on malformed specs without changing
/// the current configuration.
pub fn configure(spec: &str, seed: u64) -> Result<(), String> {
    let mut sites = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() < 3 || fields.len() > 4 {
            return Err(format!(
                "bad fault spec '{part}' (want site:kind:prob[:delay_ms])"
            ));
        }
        let kind = FaultKind::parse(fields[1])?;
        let prob: f64 = fields[2]
            .parse()
            .map_err(|_| format!("bad fault probability '{}'", fields[2]))?;
        if !(0.0..=1.0).contains(&prob) {
            return Err(format!("fault probability {prob} outside [0, 1]"));
        }
        let delay_ms = match fields.get(3) {
            Some(d) => d
                .parse()
                .map_err(|_| format!("bad fault delay '{d}'"))?,
            None => 10,
        };
        sites.push(Site {
            name: fields[0].to_string(),
            name_hash: fnv1a(fields[0]).wrapping_add(seed.rotate_left(32)),
            kind,
            prob,
            delay_ms,
            hits: AtomicU64::new(0),
            fired: AtomicU64::new(0),
        });
    }
    let armed = !sites.is_empty();
    *REGISTRY.write().unwrap() = sites;
    ARMED.store(armed, Ordering::Release);
    Ok(())
}

/// Arm from `DMA_FAULTS` / `DMA_FAULT_SEED` if set; no-op otherwise.
/// Returns the spec that was applied, if any.
pub fn configure_from_env() -> Result<Option<String>, String> {
    let Ok(spec) = std::env::var("DMA_FAULTS") else { return Ok(None) };
    if spec.trim().is_empty() {
        return Ok(None);
    }
    let seed = std::env::var("DMA_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    configure(&spec, seed)?;
    Ok(Some(spec))
}

/// Disarm all sites and clear counters.
pub fn clear() {
    ARMED.store(false, Ordering::Release);
    REGISTRY.write().unwrap().clear();
}

/// True when any site is armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Times `site` actually injected a fault since the last
/// [`configure`]/[`clear`].
pub fn fired(site: &str) -> u64 {
    REGISTRY
        .read()
        .unwrap()
        .iter()
        .filter(|s| s.name == site)
        .map(|s| s.fired.load(Ordering::Relaxed))
        .sum()
}

/// Total injected faults across all sites.
pub fn fired_total() -> u64 {
    REGISTRY
        .read()
        .unwrap()
        .iter()
        .map(|s| s.fired.load(Ordering::Relaxed))
        .sum()
}

/// Hit `site`: decide on the deterministic schedule and inject the
/// configured fault. Disarmed cost is one `Relaxed` load. `Panic`
/// panics in place; `Delay` sleeps and returns `Ok`; `Error` returns
/// `Err` for the caller to propagate.
#[inline]
pub fn check(site: &str) -> crate::Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    check_armed(site)
}

#[cold]
fn check_armed(site: &str) -> crate::Result<()> {
    let reg = REGISTRY.read().unwrap();
    for s in reg.iter().filter(|s| s.name == site) {
        let hit = s.hits.fetch_add(1, Ordering::Relaxed);
        if schedule_uniform(0, s.name_hash, hit) >= s.prob {
            continue;
        }
        s.fired.fetch_add(1, Ordering::Relaxed);
        match s.kind {
            FaultKind::Panic => {
                let msg = format!("failpoint '{site}' injected panic (hit {hit})");
                drop(reg);
                panic!("{msg}");
            }
            FaultKind::Delay => {
                let ms = s.delay_ms;
                drop(reg);
                std::thread::sleep(std::time::Duration::from_millis(ms));
                return Ok(());
            }
            FaultKind::Error => {
                drop(reg);
                return Err(anyhow::anyhow!(
                    "failpoint '{site}' injected error (hit {hit})"
                ));
            }
        }
    }
    Ok(())
}

/// Serialize tests (and benches) that arm the process-global registry.
/// Poisoned guards are recovered — a chaos test that panics on purpose
/// must not poison every later test.
pub fn exclusive() -> MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_check_is_ok_and_silent() {
        let _g = exclusive();
        clear();
        assert!(!armed());
        for _ in 0..1000 {
            check("decode_step").unwrap();
        }
        assert_eq!(fired_total(), 0);
    }

    #[test]
    fn spec_parsing_rejects_malformed() {
        let _g = exclusive();
        clear();
        assert!(configure("decode_step:panic", 0).is_err(), "missing prob");
        assert!(configure("a:bogus:0.5", 0).is_err(), "unknown kind");
        assert!(configure("a:panic:1.5", 0).is_err(), "prob > 1");
        assert!(configure("a:delay:0.5:abc", 0).is_err(), "bad delay");
        assert!(!armed(), "failed configure leaves registry disarmed");
        configure("a:error:0.5, b:delay:1:2", 7).unwrap();
        assert!(armed());
        clear();
    }

    #[test]
    fn error_schedule_is_deterministic_and_matches_prob() {
        let _g = exclusive();
        configure("site_a:error:0.25", 42).unwrap();
        let outcomes: Vec<bool> = (0..400).map(|_| check("site_a").is_err()).collect();
        let fires = outcomes.iter().filter(|&&f| f).count();
        assert!(fires > 40 && fires < 180, "~25% of 400, got {fires}");
        assert_eq!(fired("site_a") as usize, fires);
        // Same spec + seed replays the exact same schedule.
        configure("site_a:error:0.25", 42).unwrap();
        let replay: Vec<bool> = (0..400).map(|_| check("site_a").is_err()).collect();
        assert_eq!(outcomes, replay);
        // A different seed produces a different schedule.
        configure("site_a:error:0.25", 43).unwrap();
        let other: Vec<bool> = (0..400).map(|_| check("site_a").is_err()).collect();
        assert_ne!(outcomes, other);
        clear();
    }

    #[test]
    fn sites_are_independent() {
        let _g = exclusive();
        configure("only_this:error:1", 0).unwrap();
        assert!(check("only_this").is_err());
        check("some_other_site").unwrap();
        assert_eq!(fired("some_other_site"), 0);
        clear();
    }

    #[test]
    fn panic_kind_panics_in_place() {
        let _g = exclusive();
        configure("boom:panic:1", 0).unwrap();
        let caught = std::panic::catch_unwind(|| {
            let _ = check("boom");
        });
        assert!(caught.is_err());
        assert_eq!(fired("boom"), 1);
        clear();
    }

    #[test]
    fn delay_kind_sleeps_and_succeeds() {
        let _g = exclusive();
        configure("slow:delay:1:20", 0).unwrap();
        let t0 = std::time::Instant::now();
        check("slow").unwrap();
        assert!(t0.elapsed() >= std::time::Duration::from_millis(15));
        clear();
    }
}
