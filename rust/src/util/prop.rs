//! Miniature property-testing kit (proptest is not vendored).
//!
//! A property runs against `n` random cases drawn from explicit
//! generators; on failure the failing seed is reported so the case can
//! be replayed deterministically. Deliberately simple — no shrinking,
//! but seeds make failures reproducible, which is what CI needs.

use crate::util::rng::Rng;

/// Run `prop` for `cases` seeds; panic with the failing seed on error.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = 0xD1A6_0000u64 ^ (case * 0x9E37_79B9);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x} (case {case}): {msg}");
        }
    }
}

/// Assert helper that produces `Result<(), String>` for [`check`].
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Generator helpers used across property tests.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vector of normals with a random scale in [lo_scale, hi_scale].
    pub fn scaled_normals(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let scale = rng.uniform_in(lo, hi);
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    /// Random dimension that is a multiple of `m`, within [lo, hi].
    pub fn dim_multiple_of(rng: &mut Rng, m: usize, lo: usize, hi: usize) -> usize {
        let k_lo = lo.div_ceil(m);
        let k_hi = hi / m;
        (rng.int_in(k_lo as i64, k_hi as i64 + 1) as usize) * m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0u64);
        check("count", 25, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 25);
        let _ = &mut count;
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn failing_property_panics_with_seed() {
        check("boom", 10, |rng| {
            prop_assert!(rng.uniform() < 2.0); // always true
            prop_assert!(false, "forced failure");
            Ok(())
        });
    }

    #[test]
    fn dim_multiple_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let d = gen::dim_multiple_of(&mut rng, 32, 32, 256);
            assert_eq!(d % 32, 0);
            assert!((32..=256).contains(&d));
        }
    }
}
