//! Scoped-thread fan-out for the decode hot path (rayon is not
//! vendored).
//!
//! [`par_items`] runs a closure over a slice of owned work items,
//! splitting them across at most `threads` `std::thread::scope` workers.
//! Each item owns its output buffers (disjoint `&mut` slices carved out
//! by the caller), so results are identical regardless of thread count —
//! the determinism contract behind the engine's `--threads` flag.
//! `threads <= 1` (or a single item) runs inline with zero spawn
//! overhead, so the serial path is untouched.
//!
//! The hot paths have moved to the persistent [`crate::util::pool`]
//! (same contract, no per-call spawns); this scoped version remains as
//! the spawn-overhead baseline `benches/table12_decode_hotpath.rs`
//! measures the pool against, and as the dependency-free fallback.

/// Balanced partition of `len` items over `workers` chunks: the first
/// `len % workers` chunks carry one extra item, so per-worker item
/// counts never differ by more than 1. (The previous `div_ceil` split
/// could idle trailing workers entirely — 5 items over 4 workers gave
/// chunks of 2, 2, 1, 0.)
pub fn balanced_chunk_sizes(len: usize, workers: usize) -> Vec<usize> {
    let workers = workers.max(1).min(len.max(1));
    let base = len / workers;
    let extra = len % workers;
    (0..workers).map(|i| base + usize::from(i < extra)).collect()
}

/// Split `items` into the chunks described by [`balanced_chunk_sizes`].
pub(crate) fn balanced_chunks<T>(items: &mut [T], workers: usize) -> Vec<&mut [T]> {
    let sizes = balanced_chunk_sizes(items.len(), workers);
    let mut rest = items;
    let mut out = Vec::with_capacity(sizes.len());
    for sz in sizes {
        let (head, tail) = rest.split_at_mut(sz);
        out.push(head);
        rest = tail;
    }
    out
}

/// Apply `f` to every item, fanning the slice across up to `threads`
/// scoped workers. Items are processed exactly once; ordering across
/// workers is unspecified, so `f` must only touch state owned by (or
/// reachable through `Sync` references from) its item.
///
/// The calling thread works the first chunk itself, so only
/// `threads - 1` OS threads are spawned per call. Spawn cost is paid per
/// invocation (the decode path calls this once per layer); keep the
/// per-item work well above ~100us or leave `threads` at 1 — the
/// batched-decode caller splits its budget so the per-sequence and
/// per-head levels never nest multiplicatively.
pub fn par_items<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], threads: usize, f: F) {
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let mut chunks = balanced_chunks(items, threads);
    std::thread::scope(|s| {
        let mut chunks = chunks.drain(..);
        let own = chunks.next();
        for chunk in chunks {
            s.spawn(|| {
                for it in chunk {
                    f(it);
                }
            });
        }
        if let Some(chunk) = own {
            for it in chunk {
                f(it);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_items_processed_once_any_thread_count() {
        for threads in [0usize, 1, 2, 3, 7, 64] {
            let mut items: Vec<(usize, u64)> = (0..13).map(|i| (i, 0u64)).collect();
            par_items(&mut items, threads, |it| {
                it.1 += (it.0 as u64 + 1) * 10;
            });
            for (i, got) in items {
                assert_eq!(got, (i as u64 + 1) * 10, "threads {threads} item {i}");
            }
        }
    }

    #[test]
    fn partitioning_is_balanced() {
        // Per-worker item counts differ by at most 1 and every worker
        // gets work (the old div_ceil split gave 5/4 -> [2, 2, 1, 0]).
        for (len, workers) in
            [(5usize, 4usize), (13, 4), (8, 8), (7, 3), (64, 7), (2, 8), (1, 4)]
        {
            let sizes = balanced_chunk_sizes(len, workers);
            assert_eq!(sizes.iter().sum::<usize>(), len, "{len}/{workers}");
            let (mn, mx) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(mx - mn <= 1, "{len}/{workers}: unbalanced {sizes:?}");
            assert!(mn >= 1, "{len}/{workers}: idle worker in {sizes:?}");
        }
        assert_eq!(balanced_chunk_sizes(5, 4), vec![2, 1, 1, 1]);
    }

    #[test]
    fn disjoint_mut_slices_are_filled_deterministically() {
        let mut buf = vec![0f32; 24];
        let serial = {
            let mut b = vec![0f32; 24];
            for (i, chunk) in b.chunks_mut(6).enumerate() {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 100 + j) as f32;
                }
            }
            b
        };
        let mut items: Vec<(usize, &mut [f32])> = buf.chunks_mut(6).enumerate().collect();
        par_items(&mut items, 4, |(i, chunk)| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (*i * 100 + j) as f32;
            }
        });
        assert_eq!(buf, serial);
    }

    #[test]
    fn empty_slice_is_a_noop() {
        let mut items: Vec<u32> = Vec::new();
        par_items(&mut items, 8, |_| panic!("no items to visit"));
    }
}
