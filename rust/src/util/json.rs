//! Minimal JSON parser/serializer (serde is not vendored).
//!
//! Supports the full JSON data model with a DOM-style [`Json`] value.
//! Used for `model_meta.json`, the server wire protocol, and bench CSV
//! side-car metadata. Not streaming, not zero-copy — the payloads here
//! are small.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---------------------------------------------------------------
    // Accessors
    // ---------------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------------------------------------------------------
    // Builders
    // ---------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---------------------------------------------------------------
    // Parse / serialize
    // ---------------------------------------------------------------
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let bytes = input.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"s\n"],"b":false,"n":null,"o":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(rt, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a")])),
        ]);
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("y").unwrap().idx(0).unwrap().as_str(), Some("a"));
    }
}
