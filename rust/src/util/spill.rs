//! Spill-file primitives for the tiered KV memory
//! ([`crate::kvquant::tier`]): a single-writer append-mostly extent
//! file with exact-size free-extent reuse, plus the FNV-1a checksum
//! the tier index stores per spilled page.
//!
//! One [`SpillFile`] belongs to one engine worker (the engine thread is
//! the only reader and writer, so the file needs no locking). Extents
//! are written at the end of the file or into a previously freed extent
//! of *exactly* the same length — spilled radix pages of one
//! deployment share a handful of byte sizes (page geometry is fixed per
//! model; only the aged/unaged split varies), so exact-size reuse keeps
//! the file from growing across spill/reload cycles without the
//! complexity of a general allocator. The file is deleted on drop:
//! spilled pages are a cache, never durable state.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// FNV-1a over `bytes` — the per-extent checksum recorded in the tier
/// index and verified on reload (a reload must be bit-exact or fail
/// loudly; serving stale or torn planes would silently corrupt logits).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Append-mostly extent file with exact-size free-list reuse.
pub struct SpillFile {
    file: File,
    path: PathBuf,
    /// Append cursor (bytes 0..end are live or on the free list).
    end: u64,
    /// Freed extents by length: `len -> offsets` (LIFO reuse).
    free: BTreeMap<u64, Vec<u64>>,
    free_bytes: u64,
}

impl std::fmt::Debug for SpillFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillFile")
            .field("path", &self.path)
            .field("end", &self.end)
            .field("free_bytes", &self.free_bytes)
            .finish()
    }
}

impl SpillFile {
    /// Create (truncating any previous run's leftover) at `path`.
    pub fn create(path: &Path) -> std::io::Result<SpillFile> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(SpillFile {
            file,
            path: path.to_path_buf(),
            end: 0,
            free: BTreeMap::new(),
            free_bytes: 0,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes the file spans (live extents + free holes).
    pub fn file_bytes(&self) -> u64 {
        self.end
    }

    /// Bytes sitting in freed extents awaiting exact-size reuse.
    pub fn free_bytes(&self) -> u64 {
        self.free_bytes
    }

    /// Write `bytes` into a freed extent of the same length when one
    /// exists, at the end of the file otherwise. Returns the extent's
    /// offset (its length is `bytes.len()`).
    pub fn write_extent(&mut self, bytes: &[u8]) -> std::io::Result<u64> {
        let len = bytes.len() as u64;
        let offset = match self.free.get_mut(&len).and_then(Vec::pop) {
            Some(off) => {
                if self.free.get(&len).is_some_and(Vec::is_empty) {
                    self.free.remove(&len);
                }
                self.free_bytes -= len;
                off
            }
            None => {
                let off = self.end;
                self.end += len;
                off
            }
        };
        self.file.seek(SeekFrom::Start(offset))?;
        self.file.write_all(bytes)?;
        Ok(offset)
    }

    /// Read the `len` bytes at `offset` (an extent previously returned
    /// by [`Self::write_extent`] and not yet freed).
    pub fn read_extent(&mut self, offset: u64, len: u64) -> std::io::Result<Vec<u8>> {
        let mut buf = vec![0u8; len as usize];
        self.file.seek(SeekFrom::Start(offset))?;
        self.file.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Return an extent to the free list for exact-size reuse.
    pub fn free_extent(&mut self, offset: u64, len: u64) {
        self.free.entry(len).or_default().push(offset);
        self.free_bytes += len;
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        // Spill contents are a cache of resident state — never reused
        // across processes — so leave nothing behind.
        let _ = std::fs::remove_file(&self.path);
    }
}

/// A process-unique temporary directory removed (recursively) on drop —
/// the scope tests and benches run their spill files in so an aborted
/// run cannot accumulate leftovers.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "{prefix}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_is_stable_and_sensitive() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"ab"));
    }

    #[test]
    fn extents_round_trip() {
        let dir = TempDir::new("dma_spill_test").unwrap();
        let mut f = SpillFile::create(&dir.path().join("a.spill")).unwrap();
        let a = f.write_extent(&[1u8; 64]).unwrap();
        let b = f.write_extent(&[2u8; 32]).unwrap();
        assert_eq!((a, b), (0, 64));
        assert_eq!(f.read_extent(a, 64).unwrap(), vec![1u8; 64]);
        assert_eq!(f.read_extent(b, 32).unwrap(), vec![2u8; 32]);
        assert_eq!(f.file_bytes(), 96);
    }

    #[test]
    fn freed_extents_are_reused_exact_size() {
        let dir = TempDir::new("dma_spill_test").unwrap();
        let mut f = SpillFile::create(&dir.path().join("b.spill")).unwrap();
        let a = f.write_extent(&[7u8; 48]).unwrap();
        let _b = f.write_extent(&[8u8; 48]).unwrap();
        f.free_extent(a, 48);
        assert_eq!(f.free_bytes(), 48);
        // Different size: appends, hole untouched.
        let c = f.write_extent(&[9u8; 24]).unwrap();
        assert_eq!(c, 96);
        assert_eq!(f.free_bytes(), 48);
        // Same size: lands in the hole, file does not grow.
        let d = f.write_extent(&[3u8; 48]).unwrap();
        assert_eq!(d, a);
        assert_eq!(f.free_bytes(), 0);
        assert_eq!(f.file_bytes(), 120);
        assert_eq!(f.read_extent(d, 48).unwrap(), vec![3u8; 48]);
    }

    #[test]
    fn spill_file_removes_itself_and_tempdir_cleans_up() {
        let dir = TempDir::new("dma_spill_test").unwrap();
        let root = dir.path().to_path_buf();
        let p = root.join("c.spill");
        let f = SpillFile::create(&p).unwrap();
        assert!(p.exists());
        drop(f);
        assert!(!p.exists(), "spill file must be deleted on drop");
        drop(dir);
        assert!(!root.exists(), "tempdir must be removed on drop");
    }
}
