//! Infrastructure substrates built from scratch (the image is offline;
//! tokio/serde/clap/criterion/proptest are unavailable — see DESIGN.md §4).

pub mod benchkit;
pub mod cli;
pub mod failpoint;
pub mod json;
pub mod par;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod spill;
