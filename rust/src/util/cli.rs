//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — `flags` lists the
    /// option names that take no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(rest.to_string());
                    } else {
                        out.options.insert(rest.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse(flag_names: &[&str]) -> Args {
        Self::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--port", "8080", "--host=local", "run"], &[]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("host"), Some("local"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn declared_flags() {
        let a = parse(&["--verbose", "--n", "3"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--quiet"], &[]);
        assert!(a.flag("quiet"));
    }

    #[test]
    fn adjacent_flags_no_value() {
        let a = parse(&["--a", "--b", "x"], &[]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.usize_or("n", 17), 17);
        assert_eq!(a.f64_or("x", 0.5), 0.5);
        assert_eq!(a.get_or("s", "d"), "d");
    }
}
