//! `dma` — leader entrypoint for the DMA serving stack.
//!
//! Subcommands:
//!   serve  --artifacts DIR --addr HOST:PORT [--workers N] [--host-backend]
//!   eval   --artifacts DIR [--seed S] [--host-backend]
//!   smoke  --artifacts DIR            run the fn_smoke artifact
//!   info   --artifacts DIR            print the artifact inventory

use dma::config::{EngineConfig, MetaConfig};
use dma::coordinator::engine::EngineHandle;
use dma::coordinator::router::{Policy, Router};
use dma::runtime::host::HostBackend;
#[cfg(feature = "pjrt")]
use dma::runtime::pjrt::PjrtBackend;
use dma::runtime::ModelBackend;
use dma::util::cli::Args;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn usage() -> ! {
    eprintln!(
        "usage: dma <serve|eval|smoke|info> [--artifacts DIR] [--addr H:P] \
         [--workers N] [--host-backend] [--seed S] \
         [--kv-format f32|mxfp8-high|nvfp4-low|dual] \
         [--kv-policy SINK/DIAG | l0:S/D;l1:S/D;...] \
         [--prefill-chunk TOKENS] [--prefix-cache] \
         [--threads N] [--decoded-cache-mb MB] [--kv-budget-mb MB] \
         [--spec off|prompt-lookup] [--spec-k N] \
         [--writer-queue LINES] [--slow-reader-ms MS] \
         [--max-line-bytes N] \
         [--route round-robin|least-loaded|prefix-affinity] \
         [--trace-out FILE] [--metrics-sample-n N] \
         [--request-timeout-ms MS] [--queue-timeout-ms MS] \
         [--shed-policy off|degrade|spill] \
         [--kv-spill off|cold|aging] [--kv-spill-dir DIR] [--kv-age-ms MS] \
         [--fault SITE:KIND:PROB[:DELAY_MS]] [--fault-seed S]"
    );
    std::process::exit(2);
}

fn make_backend(
    artifacts: &str,
    host: bool,
) -> dma::Result<Box<dyn ModelBackend>> {
    if host {
        Ok(Box::new(HostBackend::for_tests()))
    } else {
        pjrt_backend(artifacts)
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts: &str) -> dma::Result<Box<dyn ModelBackend>> {
    let meta = MetaConfig::load(artifacts)?;
    Ok(Box::new(PjrtBackend::new(meta)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts: &str) -> dma::Result<Box<dyn ModelBackend>> {
    anyhow::bail!(
        "dma was built without the `pjrt` feature; rebuild with \
         `--features pjrt` or pass --host-backend"
    )
}

fn cmd_serve(args: &Args) -> dma::Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let addr = args.get_or("addr", "127.0.0.1:7433");
    let workers = args.usize_or("workers", 1);
    let host = args.flag("host-backend");
    let meta = if host { None } else { Some(MetaConfig::load(&artifacts)?) };
    let eos = meta.as_ref().map_or(5, |m| m.tokens.eos);
    let kv_format = match args.get("kv-format") {
        Some(s) => dma::kvquant::KvFormat::parse(s)?,
        None => dma::kvquant::KvFormat::F32,
    };
    if kv_format != dma::kvquant::KvFormat::F32 && !host {
        anyhow::bail!(
            "--kv-format {} requires --host-backend (PJRT executables take f32 caches)",
            kv_format.name()
        );
    }
    let prefix_cache = args.flag("prefix-cache");
    if prefix_cache && kv_format == dma::kvquant::KvFormat::F32 {
        anyhow::bail!(
            "--prefix-cache shares quantized pages; pick a quantized --kv-format \
             (mxfp8-high, nvfp4-low or dual)"
        );
    }
    let prefill_chunk = args.usize_or("prefill-chunk", 32);
    // Precision-policy precedence: CLI > AOT bundle export > built-in.
    let kv_precision_policies = match args.get("kv-policy") {
        Some(s) => dma::kvquant::KvPolicy::parse_layers(s)?,
        None => match meta.as_ref().filter(|m| !m.kv_precision_policies.is_empty()) {
            Some(m) => m.kv_precision_policies.clone(),
            None => vec![dma::kvquant::KvPolicy::default()],
        },
    };
    let threads = args.usize_or("threads", 1).max(1);
    let decoded_cache_bytes = args
        .usize_or("decoded-cache-mb", dma::kvquant::DECODED_CACHE_BYTES >> 20)
        << 20;
    // 0 = derive the pool budget from the decode slots (the default).
    let kv_budget_bytes = args.usize_or("kv-budget-mb", 0) << 20;
    let metrics_sample_n = args.usize_or("metrics-sample-n", 0);
    let spec = match args.get("spec") {
        Some(s) => dma::spec::SpecMode::parse(s)?,
        None => dma::spec::SpecMode::Off,
    };
    let spec_k = args.usize_or("spec-k", 4);
    if spec.enabled() && spec_k == 0 {
        anyhow::bail!("--spec {} needs --spec-k >= 1", spec.name());
    }
    // Deterministic fault injection (see util::failpoint): the CLI spec
    // wins over the DMA_FAULTS / DMA_FAULT_SEED environment. Armed
    // before the workers spawn so every site fires from step one.
    let fault_summary = match args.get("fault") {
        Some(spec) => {
            let fault_seed = args.usize_or("fault-seed", 0) as u64;
            dma::util::failpoint::configure(spec, fault_seed)
                .map_err(|e| anyhow::anyhow!("--fault: {e}"))?;
            Some(format!("{spec} (seed {fault_seed})"))
        }
        None => dma::util::failpoint::configure_from_env()
            .map_err(|e| anyhow::anyhow!("DMA_FAULTS: {e}"))?,
    };
    let shed_policy = match args.get("shed-policy") {
        Some(s) => dma::config::ShedPolicy::parse(s)?,
        None => dma::config::ShedPolicy::Off,
    };
    let kv_spill = match args.get("kv-spill") {
        Some(s) => dma::kvquant::tier::TierMode::parse(s)?,
        None => dma::kvquant::tier::TierMode::Off,
    };
    let kv_spill_dir = args.get("kv-spill-dir").map(std::path::PathBuf::from);
    let kv_age_ms = args.usize_or("kv-age-ms", 250) as u64;
    if kv_spill.enabled() && !prefix_cache {
        anyhow::bail!(
            "--kv-spill {} tiers shared radix pages; it needs --prefix-cache \
             (and therefore a quantized --kv-format)",
            kv_spill.name()
        );
    }
    if shed_policy == dma::config::ShedPolicy::Spill && !kv_spill.enabled() {
        anyhow::bail!("--shed-policy spill needs --kv-spill cold|aging");
    }
    let cfg = EngineConfig {
        artifact_dir: artifacts.clone().into(),
        max_new_tokens: args.usize_or("max-new-tokens", 32),
        prefill_chunk,
        prefix_cache,
        kv_format,
        kv_precision_policies,
        threads,
        decoded_cache_bytes,
        kv_budget_bytes,
        metrics_sample_n,
        spec,
        spec_k,
        request_timeout_ms: args.usize_or("request-timeout-ms", 0) as u64,
        queue_timeout_ms: args.usize_or("queue-timeout-ms", 0) as u64,
        shed_policy,
        kv_spill,
        kv_spill_dir,
        kv_age_ms,
        ..Default::default()
    };
    let policy = match args.get_or("route", "least-loaded").as_str() {
        "round-robin" => Policy::RoundRobin,
        "least-loaded" => Policy::LeastLoaded,
        // Affinity keys on the same chunk-aligned prefix the radix
        // caches share at, so repeat prefixes hit a warm worker.
        "prefix-affinity" => Policy::PrefixAffinity {
            chunk_tokens: cfg.prefill_chunk.max(1),
        },
        other => anyhow::bail!("unknown --route {other:?}"),
    };
    // The serve path always runs with telemetry attached (idle cost is a
    // handful of atomics); the trace sink and layer probe stay opt-in.
    let mut telemetry = dma::telemetry::Telemetry::new();
    if metrics_sample_n > 0 {
        telemetry = telemetry.with_probe(metrics_sample_n as u64);
    }
    let trace_out = args.get("trace-out").map(str::to_string);
    if let Some(path) = &trace_out {
        let sink = dma::telemetry::TraceSink::create(std::path::Path::new(path))
            .map_err(|e| anyhow::anyhow!("creating --trace-out {path}: {e}"))?;
        telemetry = telemetry.with_trace(sink);
    }
    let telemetry = Arc::new(telemetry);
    let handles: Vec<EngineHandle> = (0..workers)
        .map(|i| {
            let a = artifacts.clone();
            let c = cfg.clone();
            let t = telemetry.clone();
            EngineHandle::spawn_with_telemetry(move || make_backend(&a, host), c, eos, t, i)
        })
        .collect();
    let router = Arc::new(Router::with_telemetry(handles, policy, telemetry));
    let stop = Arc::new(AtomicBool::new(false));
    let defaults = dma::server::ServerOpts::default();
    let opts = dma::server::ServerOpts {
        writer_queue_lines: args
            .usize_or("writer-queue", defaults.writer_queue_lines)
            .max(1),
        slow_reader_timeout: std::time::Duration::from_millis(
            args.usize_or(
                "slow-reader-ms",
                defaults.slow_reader_timeout.as_millis() as usize,
            ) as u64,
        ),
        max_line_bytes: args
            .usize_or("max-line-bytes", defaults.max_line_bytes)
            .max(64),
    };
    println!(
        "dma: serving on {addr} ({} worker(s), route {}, kv cache {}, policy {}, \
         prefill chunk {}, prefix cache {}, threads {}, decoded cache {} MiB, \
         spec {}, writer queue {} lines / {} ms slow-reader timeout, trace {}, \
         layer probe {}, shed {}, kv spill {}, timeouts req/queue {}/{} ms, faults {})",
        workers,
        policy.name(),
        cfg.kv_format.name(),
        dma::kvquant::KvPolicy::format_layers(&cfg.kv_precision_policies),
        cfg.prefill_chunk,
        if cfg.prefix_cache { "on" } else { "off" },
        cfg.threads,
        cfg.decoded_cache_bytes >> 20,
        if cfg.spec.enabled() {
            format!("{} k={}", cfg.spec.name(), cfg.spec_k)
        } else {
            "off".to_string()
        },
        opts.writer_queue_lines,
        opts.slow_reader_timeout.as_millis(),
        trace_out.as_deref().unwrap_or("off"),
        if metrics_sample_n > 0 {
            format!("every {metrics_sample_n} steps")
        } else {
            "off".to_string()
        },
        cfg.shed_policy.name(),
        if cfg.kv_spill.enabled() {
            format!(
                "{} (dir {}, age {} ms)",
                cfg.kv_spill.name(),
                cfg.kv_spill_dir
                    .as_deref()
                    .map_or_else(|| "auto".to_string(), |p| p.display().to_string()),
                cfg.kv_age_ms
            )
        } else {
            "off".to_string()
        },
        cfg.request_timeout_ms,
        cfg.queue_timeout_ms,
        fault_summary.as_deref().unwrap_or("off")
    );
    dma::server::serve_with(&addr, router, opts, stop, |a| println!("dma: bound {a}"))
}

fn cmd_eval(args: &Args) -> dma::Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let seed = args.usize_or("seed", 7) as u64;
    let host = args.flag("host-backend");
    let (mut backend, ids, shapes): (Box<dyn ModelBackend>, _, _) = if host {
        let be = HostBackend::for_tests();
        let ids = dma::config::TokenIds {
            pad: 0, bos: 1, sep: 2, qry: 3, mrk: 4, eos: 5,
            payload_start: 6, vocab: 64,
        };
        (Box::new(be) as Box<dyn ModelBackend>, ids, vec![(2usize, 32usize)])
    } else {
        pjrt_eval_parts(&artifacts)?
    };
    println!("Table 3 (synthetic LongBench proxy) — native vs DMA");
    println!("{:<16} {:>8} {:>8}", "task", "native", "dma");
    let rows = dma::eval::run_suite(backend.as_mut(), &ids, &shapes, seed)?;
    let (mut sn, mut sd) = (0.0, 0.0);
    for r in &rows {
        println!("{:<16} {:>8.3} {:>8.3}", r.task, r.native, r.dma);
        sn += r.native;
        sd += r.dma;
    }
    println!("{:<16} {:>8.3} {:>8.3}", "Avg.", sn / rows.len() as f64,
             sd / rows.len() as f64);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_eval_parts(
    artifacts: &str,
) -> dma::Result<(Box<dyn ModelBackend>, dma::config::TokenIds, Vec<(usize, usize)>)> {
    let meta = MetaConfig::load(artifacts)?;
    let ids = meta.tokens;
    let shapes = meta.eval_shapes.clone();
    Ok((Box::new(PjrtBackend::new(meta)?), ids, shapes))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_eval_parts(
    _artifacts: &str,
) -> dma::Result<(Box<dyn ModelBackend>, dma::config::TokenIds, Vec<(usize, usize)>)> {
    anyhow::bail!(
        "dma was built without the `pjrt` feature; rebuild with \
         `--features pjrt` or pass --host-backend"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_smoke(args: &Args) -> dma::Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let meta = MetaConfig::load(&artifacts)?;
    let mut be = PjrtBackend::new(meta)?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let outs = be.run("fn_smoke", false, vec![x, y])?;
    let v: Vec<f32> = outs[0].to_vec()?;
    anyhow::ensure!(v == vec![5., 5., 9., 9.], "unexpected smoke output {v:?}");
    println!("smoke OK: {v:?}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_smoke(_args: &Args) -> dma::Result<()> {
    anyhow::bail!("the smoke subcommand requires the `pjrt` feature")
}

fn cmd_info(args: &Args) -> dma::Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let meta = MetaConfig::load(&artifacts)?;
    println!("model: {:?}", meta.model);
    println!("cache_len: {}", meta.cache_len);
    println!("prefill buckets: {:?}", meta.prefill_lens);
    println!("decode buckets:  {:?}", meta.decode_batches);
    println!("eval shapes:     {:?}", meta.eval_shapes);
    println!("params: {} tensors", meta.param_order.len());
    Ok(())
}

fn main() {
    let args = Args::parse(&["host-backend", "prefix-cache"]);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    let result = match cmd {
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "smoke" => cmd_smoke(&args),
        "info" => cmd_info(&args),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("dma {cmd}: error: {e:#}");
        std::process::exit(1);
    }
}
