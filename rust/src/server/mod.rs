//! TCP JSON-lines serving front end (std::net — tokio is not vendored).
//!
//! Protocol v2.5: one JSON object per line.
//!
//! Request fields (`tokens` required, everything else optional):
//!
//! ```text
//! -> {"id": 1, "tokens": [1,7,9], "max_new_tokens": 8, "dma": true,
//!     "temperature": 0.8, "top_k": 40, "top_p": 0.95, "seed": 7,
//!     "stop": [5, 12], "ignore_eos": false, "stream": true,
//!     "n": 2, "best_of": 4, "logprobs": true}
//! ```
//!
//! `temperature: 0` (the default) is greedy decoding; any other value
//! samples deterministically from the request's `seed`. `n` asks for
//! that many parallel samples (one prompt prefill, quantized KV forked
//! copy-on-write per candidate); `best_of` generates that many
//! candidates and keeps the `n` best by cumulative logprob;
//! `logprobs: true` adds per-token logprobs to the wire. A
//! non-streaming request gets exactly one summary line — for `n = 1`
//! without `logprobs` its shape is exactly the v2 contract:
//!
//! ```text
//! <- {"id": 1, "output": [12, 5], "finish": "eos", "queue_ms": 0.1,
//!     "prefill_ms": 3.2, "decode_ms": 8.9, "ttft_ms": 3.4}
//! ```
//!
//! With `n > 1` the summary gains a `candidates` array (best first —
//! cumulative logprob descending, candidate index breaking ties;
//! `output`/`finish` mirror the best candidate); with `logprobs` it
//! gains `cum_logprob` plus per-token `logprobs` (top level for the
//! best candidate, per entry inside `candidates`):
//!
//! ```text
//! <- {"id": 1, "output": [12, 5], "finish": "eos", ...,
//!     "candidates": [
//!       {"candidate": 0, "output": [12, 5], "finish": "eos",
//!        "cum_logprob": -1.7},
//!       {"candidate": 1, "output": [12, 9], "finish": "eos",
//!        "cum_logprob": -2.3}]}
//! ```
//!
//! A streaming request receives its event stream as it happens — a
//! `started` line, one `token` line per generated token (tagged with
//! the producing `candidate`; `logprob` added when requested), then the
//! same summary line tagged `"event": "finished"`:
//!
//! ```text
//! <- {"id": 1, "event": "started", "queue_ms": 0.1}
//! <- {"id": 1, "event": "token", "candidate": 0, "token": 12,
//!     "index": 0, "decode_ms": 0}
//! <- {"id": 1, "event": "token", "candidate": 1, "token": 12,
//!     "index": 0, "decode_ms": 0}
//! <- {"id": 1, "event": "finished", "output": [...], ...}
//! ```
//!
//! Control messages:
//!
//! ```text
//! -> {"cmd": "cancel", "id": 1}   cancel that request (this connection's
//!                                 id namespace); its terminal line
//!                                 reports "finish": "cancelled"
//! -> {"cmd": "cancel", "id": 1, "candidate": 2}
//!                                 cancel one candidate; its siblings
//!                                 keep generating (the terminal line
//!                                 arrives when the last one finishes)
//! -> {"cmd": "stats"}
//! <- {"workers": 1, "policy": "least-loaded", "kv_format": "f32",
//!     "kv_policy": "128/128", "prefix_hit_tokens": 0,
//!     "kv_bytes_in_use": 0, "decoded_page_hits": 0,
//!     "decoded_page_misses": 0, "decoded_page_hit_rate": 0}
//! ```
//!
//! New in v2.2: when the server was started with telemetry attached
//! (the `serve` subcommand always does), the `stats` reply additionally
//! carries latency summaries and rolling-window gauges — nested
//! `{"count", "p50_ms", "p90_ms", "p99_ms", "mean_ms"}` objects under
//! `ttft`, `inter_token`, `decode_step`, `queue`, plus flat
//! `tokens_per_second_10s`, `ttft_ms_10s`, `requests_completed`, and
//! `requests_cancelled` — and a `metrics` command exposes the full
//! Prometheus text exposition (every histogram, counter, and per-worker
//! gauge; see the crate's README "Observability" section):
//!
//! ```text
//! -> {"cmd": "metrics"}
//! <- {"metrics": "# HELP dma_ttft_seconds ...\n# TYPE ...\n..."}
//! ```
//!
//! The text lives in one JSON string field (`\n`-escaped) so the reply
//! stays a single line like every other protocol message; a scraper
//! unescapes the field to recover the standard exposition format.
//!
//! New in v2.3: the telemetry-backed `stats` reply splits cancellation
//! counts into a nested `"cancelled": {"groups", "candidates"}` object
//! (v2.2's flat `requests_cancelled` — whole groups only — stays for
//! compatibility; `candidates` counts individual candidates cancelled
//! out of groups that kept running, which the flat field conflated with
//! nothing at all), and reports the speculative-decoding configuration
//! and counters under a nested `"spec"` object:
//!
//! ```text
//! <- {..., "cancelled": {"groups": 0, "candidates": 0},
//!     "spec": {"mode": "prompt-lookup", "k": 4, "rounds": 31,
//!              "proposed_tokens": 92, "accepted_tokens": 61,
//!              "rolled_back_tokens": 24}}
//! ```
//!
//! `mode`/`k` echo the `--spec`/`--spec-k` the server was started with
//! (`"mode": "off"` and zero counters when speculation is disabled);
//! `rounds` counts per-candidate verification rounds, and the token
//! counters are cumulative across the fleet.
//!
//! New in v2.4 (resilience):
//!
//! * Requests accept `"deadline_ms"` — a total time budget measured
//!   from enqueue. A request that exceeds it (or the server-wide
//!   `--request-timeout-ms` / `--queue-timeout-ms` bounds) is finished
//!   early with `"finish": "timeout"`, keeping whatever tokens it had
//!   produced.
//! * A summary rejected by KV-pressure load shedding
//!   (`--shed-policy degrade`) carries `"retry_after_ms"` — the
//!   client's backoff hint, derived from the rolling throughput window.
//! * A streamed request that survived a worker crash sees one marker
//!   line before its stream resumes:
//!   `{"id": 1, "event": "restarted", "replayed_tokens": 3}`. The
//!   request was re-dispatched from its prompt on a fresh worker;
//!   the first `replayed_tokens` positions are regenerated internally
//!   and (being greedy/seeded) reproduce the already-delivered tokens
//!   bit-exactly, so they are *not* re-sent — the next `token` line
//!   after the marker continues where the stream left off.
//! * The telemetry-backed `stats` reply gains a nested `"resilience"`
//!   object (`worker_restarts`, `requests_replayed`, `requests_shed`,
//!   `deadline_cancels`), and the `metrics` exposition the matching
//!   `dma_worker_restarts_total`, `dma_requests_replayed_total`,
//!   `dma_requests_shed_total`, cause-labelled
//!   `dma_deadline_cancels_total`, and per-worker `dma_worker_healthy`
//!   families.
//! * Connection hardening: an inbound line longer than
//!   [`ServerOpts::max_line_bytes`] gets a structured `{"error": ...}`
//!   reply and a clean close (the oversized tail is never buffered);
//!   bytes that are not valid UTF-8, and a half-frame cut off by a
//!   disconnect, get a structured error instead of a silent hang.
//!
//! New in v2.5 (tiered KV memory):
//!
//! * The `stats` reply always carries a nested `"tier"` object — no
//!   telemetry required, mode `"off"` and zeros when `--kv-spill` is
//!   disabled:
//!
//! ```text
//! <- {..., "tier": {"mode": "aging", "hot_pages": 12, "aged_pages": 3,
//!     "spilled_pages": 40, "spilled_bytes": 281600, "pages_aged": 9,
//!     "pages_spilled": 44, "pages_reloaded": 4, "spill_bytes": 309760,
//!     "reload_bytes": 28160}}
//! ```
//!
//!   `hot_pages`/`aged_pages`/`spilled_pages`/`spilled_bytes` are
//!   residency gauges (hot pages hold every precision plane, aged pages
//!   serve from their NVFP4 copy, spilled pages live in the per-worker
//!   spill files); the rest are cumulative counters, fleet-wide.
//! * The `metrics` exposition gains `dma_kv_spill_bytes_total`,
//!   `dma_kv_reload_bytes_total`, `dma_kv_pages_aged_total`, the
//!   `dma_kv_reload_seconds` histogram, tier-labelled
//!   `dma_kv_tier_pages` gauges, and the `dma_kv_spilled_bytes` gauge.
//!
//! **Back-pressure / slow readers.** Each connection's outbound lines
//! flow through a *bounded* writer channel
//! ([`ServerOpts::writer_queue_lines`]). When a client stops reading
//! and the queue fills, the dispatcher blocks on that connection for at
//! most [`ServerOpts::slow_reader_timeout`], then declares the
//! connection dead: every request it still has in flight is cancelled
//! (KV pages released), its registrations are dropped, and its socket
//! is force-closed so both connection threads unblock and exit — a
//! stalled consumer can no longer grow an unbounded event backlog, pin
//! cache pages, or leak its thread pair. A clean disconnect cancels the
//! connection's in-flight requests the same way.
//!
//! Events are routed back to the connection that submitted them by an
//! internal request id (client-supplied ids are echoed but may collide
//! across connections): each accepted request registers a per-connection
//! channel with the dispatcher, which drains the routers' event streams
//! and forwards each event to its owner.

use crate::coordinator::router::Router;
use crate::coordinator::{EngineEvent, Request, Response, SamplingParams};
use crate::util::json::Json;
use anyhow::Context;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Server tuning knobs (the protocol itself is not configurable).
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// Capacity of each connection's outbound line queue. Full means
    /// the client is not reading as fast as the engine produces.
    pub writer_queue_lines: usize,
    /// How long the dispatcher blocks on one connection's full queue
    /// before declaring it dead and auto-cancelling its requests.
    pub slow_reader_timeout: Duration,
    /// Longest inbound line accepted. A longer line is answered with a
    /// structured error and the connection is closed — the tail of the
    /// oversized line is never pulled into memory, so a misbehaving (or
    /// malicious) client cannot balloon the server's heap.
    pub max_line_bytes: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            writer_queue_lines: 1024,
            slow_reader_timeout: Duration::from_secs(2),
            max_line_bytes: 1 << 20,
        }
    }
}

/// A parsed inbound request line.
pub struct ParsedRequest {
    pub req: Request,
    /// The id to echo back to the client (defaults to the internal id).
    pub client_id: u64,
    /// Stream per-token events to the client.
    pub stream: bool,
}

pub fn parse_request(line: &str, internal_id: u64) -> Result<ParsedRequest, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let tokens = j
        .get("tokens")
        .and_then(Json::as_arr)
        .ok_or("missing tokens")?
        .iter()
        .map(|v| v.as_i64().map(|x| x as i32))
        .collect::<Option<Vec<i32>>>()
        .ok_or("tokens must be integers")?;
    let client_id = j
        .get("id")
        .and_then(Json::as_i64)
        .map(|v| v as u64)
        .unwrap_or(internal_id);
    let stop = match j.get("stop") {
        None => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or("stop must be an array of token ids")?
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32))
            .collect::<Option<Vec<i32>>>()
            .ok_or("stop tokens must be integers")?,
    };
    let sampling = SamplingParams {
        temperature: j
            .get("temperature")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as f32,
        top_k: j.get("top_k").and_then(Json::as_usize).unwrap_or(0),
        top_p: j.get("top_p").and_then(Json::as_f64).unwrap_or(1.0) as f32,
        seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
        stop,
        ignore_eos: j.get("ignore_eos").and_then(Json::as_bool).unwrap_or(false),
        n: j.get("n").and_then(Json::as_usize).unwrap_or(1),
        best_of: j.get("best_of").and_then(Json::as_usize).unwrap_or(0),
        logprobs: j.get("logprobs").and_then(Json::as_bool).unwrap_or(false),
        deadline_ms: j
            .get("deadline_ms")
            .and_then(Json::as_i64)
            .map(|v| v.max(0) as u64)
            .unwrap_or(0),
    };
    Ok(ParsedRequest {
        req: Request {
            id: internal_id,
            tokens,
            max_new_tokens: j
                .get("max_new_tokens")
                .and_then(Json::as_usize)
                .unwrap_or(16),
            dma: j.get("dma").and_then(Json::as_bool).unwrap_or(true),
            sampling,
        },
        client_id,
        stream: j.get("stream").and_then(Json::as_bool).unwrap_or(false),
    })
}

/// Serialize a terminal response. The `n = 1` / no-logprobs shape is
/// exactly the v2 wire contract; groups add a `candidates` array and
/// the `logprobs` flag adds `cum_logprob` + per-token `logprobs`.
pub fn response_json(r: &Response, logprobs: bool) -> Json {
    let mut fields = vec![
        ("id", Json::num(r.id as f64)),
        (
            "output",
            Json::arr(r.output.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("finish", Json::str(r.finish.as_str())),
        ("queue_ms", Json::num(r.queue_ms)),
        ("prefill_ms", Json::num(r.prefill_ms)),
        ("decode_ms", Json::num(r.decode_ms)),
        ("ttft_ms", Json::num(r.ttft_ms)),
    ];
    if logprobs {
        if let Some(best) = r.candidates.first() {
            fields.push(("cum_logprob", Json::num(best.cum_logprob)));
            fields.push((
                "logprobs",
                Json::arr(best.logprobs.iter().map(|&l| Json::num(l as f64)).collect()),
            ));
        }
    }
    if r.candidates.len() > 1 {
        let cands = r
            .candidates
            .iter()
            .map(|c| {
                let mut cf = vec![
                    ("candidate", Json::num(c.candidate as f64)),
                    (
                        "output",
                        Json::arr(c.output.iter().map(|&t| Json::num(t as f64)).collect()),
                    ),
                    ("finish", Json::str(c.finish.as_str())),
                    ("cum_logprob", Json::num(c.cum_logprob)),
                ];
                if logprobs {
                    cf.push((
                        "logprobs",
                        Json::arr(c.logprobs.iter().map(|&l| Json::num(l as f64)).collect()),
                    ));
                }
                Json::obj(cf)
            })
            .collect();
        fields.push(("candidates", Json::arr(cands)));
    }
    if let Some(e) = &r.error {
        fields.push(("error", Json::str(e.clone())));
    }
    if let Some(ms) = r.retry_after_ms {
        fields.push(("retry_after_ms", Json::num(ms as f64)));
    }
    Json::obj(fields)
}

/// Wire form of one event. Non-streaming requests only ever see the
/// summary (their `Finished` serializes exactly as in protocol v2 for
/// `n = 1`); streamed events carry an `"event"` tag, token lines a
/// `candidate` tag, and `logprob` when the request asked for it.
pub fn event_json(ev: &EngineEvent, stream: bool, logprobs: bool) -> Json {
    match ev {
        EngineEvent::Started { id, queue_ms } => Json::obj(vec![
            ("id", Json::num(*id as f64)),
            ("event", Json::str("started")),
            ("queue_ms", Json::num(*queue_ms)),
        ]),
        EngineEvent::Token { id, candidate, token, index, logprob, decode_ms } => {
            let mut fields = vec![
                ("id", Json::num(*id as f64)),
                ("event", Json::str("token")),
                ("candidate", Json::num(*candidate as f64)),
                ("token", Json::num(*token as f64)),
                ("index", Json::num(*index as f64)),
                ("decode_ms", Json::num(*decode_ms)),
            ];
            if logprobs {
                fields.push(("logprob", Json::num(*logprob as f64)));
            }
            Json::obj(fields)
        }
        EngineEvent::Restarted { id, replayed_tokens } => Json::obj(vec![
            ("id", Json::num(*id as f64)),
            ("event", Json::str("restarted")),
            ("replayed_tokens", Json::num(*replayed_tokens as f64)),
        ]),
        EngineEvent::Finished(r) => {
            let mut j = response_json(r, logprobs);
            if stream {
                if let Json::Obj(m) = &mut j {
                    m.insert("event".into(), Json::str("finished"));
                }
            }
            j
        }
    }
}

/// Per-connection control shared between the connection's threads and
/// the dispatcher: the dead flag plus the socket handle the dispatcher
/// shuts down to *unblock* an abandoned connection — a reader parked in
/// a blocking line read would otherwise never observe the flag, leaking
/// the reader/writer thread pair and the socket.
struct ConnCtl {
    dead: AtomicBool,
    /// Socket clone force-closed on abandon (`None` only in unit tests
    /// that drive [`dispatch_event`] without a real connection).
    sock: Option<TcpStream>,
}

impl ConnCtl {
    /// Mark the connection dead and close its socket so both of its
    /// threads come unstuck (the reader's blocking read errors out, the
    /// writer's next write fails).
    fn kill(&self) {
        self.dead.store(true, Ordering::Relaxed);
        if let Some(s) = &self.sock {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

struct PendingEntry {
    client_id: u64,
    stream: bool,
    /// Include logprobs on this request's wire lines.
    logprobs: bool,
    /// Owning connection id (for slow-reader group cancellation).
    conn: u64,
    /// Owning connection's control block (dead flag + socket handle).
    ctl: Arc<ConnCtl>,
    /// The owning connection's *bounded* outbound line channel. Every
    /// byte that reaches a socket goes through its connection's single
    /// writer thread — reader-side control replies included — so lines
    /// can never interleave mid-write.
    tx: mpsc::SyncSender<String>,
}

/// internal id -> owning connection registration.
type Pending = Arc<Mutex<HashMap<u64, PendingEntry>>>;

/// Push one line into a bounded writer queue, blocking up to `timeout`
/// when it is full. False means the line could not be delivered (queue
/// still full — a slow reader — or the writer is gone).
fn send_with_timeout(tx: &mpsc::SyncSender<String>, line: String, timeout: Duration) -> bool {
    // Fault-injection site: an injected error here makes the line
    // undeliverable, which the dispatcher treats exactly like a slow
    // reader (connection abandoned, in-flight requests cancelled).
    if crate::util::failpoint::check("writer_queue").is_err() {
        return false;
    }
    let mut line = match tx.try_send(line) {
        Ok(()) => return true,
        Err(mpsc::TrySendError::Disconnected(_)) => return false,
        Err(mpsc::TrySendError::Full(l)) => l,
    };
    let deadline = std::time::Instant::now() + timeout;
    loop {
        std::thread::sleep(Duration::from_millis(1));
        match tx.try_send(line) {
            Ok(()) => return true,
            Err(mpsc::TrySendError::Disconnected(_)) => return false,
            Err(mpsc::TrySendError::Full(l)) => {
                if std::time::Instant::now() >= deadline {
                    return false;
                }
                line = l;
            }
        }
    }
}

/// Outcome of one bounded line read ([`read_line_bounded`]).
enum LineRead {
    /// A complete line is in the buffer (terminator stripped). EOF with
    /// trailing unterminated bytes — a frame cut off mid-line by a
    /// disconnect — also lands here so the caller can report it; the
    /// *next* read returns `Eof`.
    Line,
    /// Clean EOF: no pending bytes.
    Eof,
    /// The line exceeds the cap. The buffer holds a truncated prefix
    /// and the remainder was left unconsumed — there is no way to
    /// resync mid-line without buffering it, so the caller must close.
    TooLong,
}

/// Read one `\n`-terminated line into `buf` without ever buffering more
/// than `max` bytes of it. The unbounded-allocation alternative
/// (`BufRead::read_line`) would let one hostile line grow the heap by
/// its full length before the server could react.
fn read_line_bounded(
    r: &mut impl BufRead,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok(if buf.is_empty() { LineRead::Eof } else { LineRead::Line });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if buf.len() + i > max {
                    return Ok(LineRead::TooLong);
                }
                buf.extend_from_slice(&chunk[..i]);
                r.consume(i + 1);
                return Ok(LineRead::Line);
            }
            None => {
                let n = chunk.len();
                if buf.len() + n > max {
                    return Ok(LineRead::TooLong);
                }
                buf.extend_from_slice(chunk);
                r.consume(n);
            }
        }
    }
}

/// Connection writer body: drain the bounded line queue onto the
/// socket. Exits when every sender is gone, a write fails, *or* the
/// connection is declared dead — the periodic dead-flag check is the
/// point: a plain blocking `recv` would keep an abandoned connection's
/// writer parked for as long as any sender clone survived (the reader
/// thread can hold one for seconds while it times out a reply), leaking
/// the thread pair the abandon was supposed to reap.
fn writer_loop(rx: mpsc::Receiver<String>, mut sock: impl Write, ctl: &ConnCtl) {
    loop {
        if ctl.dead.load(Ordering::Relaxed) {
            return; // dropping `rx` discards whatever was still queued
        }
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(line) => {
                if writeln!(sock, "{line}").is_err() {
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Declare connection `conn` dead: close its socket (unblocking its
/// reader/writer threads), drop every registration it owns, and cancel
/// its in-flight requests so abandoned generations release their KV
/// pages instead of decoding into a full queue forever.
fn abandon_connection(conn: u64, ctl: &ConnCtl, pending: &Pending, router: &Router) {
    ctl.kill();
    let ids: Vec<u64> = {
        let mut map = pending.lock().unwrap();
        let ids: Vec<u64> =
            map.iter().filter(|(_, e)| e.conn == conn).map(|(id, _)| *id).collect();
        for id in &ids {
            map.remove(id);
        }
        ids
    };
    for id in ids {
        let _ = router.cancel(id);
    }
}

/// Route one engine event to its owning connection (dispatcher body,
/// factored out for the slow-reader tests). Token/Started events are
/// forwarded only to streaming registrations; the terminal event
/// releases the registration. A connection whose queue stays full past
/// `timeout` is abandoned via [`abandon_connection`].
fn dispatch_event(mut ev: EngineEvent, pending: &Pending, router: &Router, timeout: Duration) {
    let internal = ev.id();
    let terminal = matches!(ev, EngineEvent::Finished(_));
    // Hold the registry lock only for the map operation; serialization
    // and (bounded) sending happen outside so per-token work never
    // blocks connection submit paths.
    let route = {
        let mut map = pending.lock().unwrap();
        if terminal {
            map.remove(&internal)
                .map(|e| (e.stream, e.logprobs, e.client_id, e.conn, e.ctl, e.tx))
        } else {
            match map.get(&internal) {
                Some(e) if e.stream => Some((
                    true,
                    e.logprobs,
                    e.client_id,
                    e.conn,
                    e.ctl.clone(),
                    e.tx.clone(),
                )),
                _ => None,
            }
        }
    };
    if let Some((stream_mode, logprobs, client_id, conn, ctl, tx)) = route {
        ev.set_id(client_id);
        let line = event_json(&ev, stream_mode, logprobs).to_string();
        if !send_with_timeout(&tx, line, timeout) {
            abandon_connection(conn, &ctl, pending, router);
        }
    }
}

/// Serve until `stop` is set, with default [`ServerOpts`]. The bound
/// address is reported through `on_bind` (tests connect to an ephemeral
/// port).
pub fn serve(
    addr: &str,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    on_bind: impl FnOnce(std::net::SocketAddr),
) -> crate::Result<()> {
    serve_with(addr, router, ServerOpts::default(), stop, on_bind)
}

/// [`serve`] with explicit back-pressure knobs.
pub fn serve_with(
    addr: &str,
    router: Arc<Router>,
    opts: ServerOpts,
    stop: Arc<AtomicBool>,
    on_bind: impl FnOnce(std::net::SocketAddr),
) -> crate::Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bind(listener.local_addr()?);

    let pending: Pending = Arc::new(Mutex::new(HashMap::new()));
    let next_id = Arc::new(AtomicU64::new(1));

    // Dispatcher: drain worker events, route each to its owner.
    let dispatcher = {
        let router = router.clone();
        let pending = pending.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let got = router.poll_events(64);
                if got.is_empty() {
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                for ev in got {
                    dispatch_event(ev, &pending, &router, opts.slow_reader_timeout);
                }
            }
        })
    };

    let mut handles = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let router = router.clone();
                let pending = pending.clone();
                let next_id = next_id.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &router, &pending, &next_id, opts) {
                        eprintln!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                let _ = dispatcher.join();
                return Err(e.into());
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let _ = dispatcher.join();
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    pending: &Pending,
    next_id: &AtomicU64,
    opts: ServerOpts,
) -> crate::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let (tx_conn, rx_conn) = mpsc::sync_channel::<String>(opts.writer_queue_lines.max(1));
    // The connection id shares the request-id counter: both only need
    // uniqueness, and one counter cannot collide with itself.
    let conn_id = next_id.fetch_add(1, Ordering::Relaxed);
    let ctl = Arc::new(ConnCtl {
        dead: AtomicBool::new(false),
        sock: stream.try_clone().ok(),
    });

    // Writer half: the connection's only socket writer. Event lines
    // (from the dispatcher) and control replies (from the reader loop)
    // all arrive here as whole lines, so they can never interleave
    // mid-write. Runs until every sender (reader + dispatcher-held
    // registrations) is gone or the connection is declared dead.
    let wstream = stream;
    let wctl = ctl.clone();
    let writer_thread = std::thread::spawn(move || writer_loop(rx_conn, wstream, &wctl));
    // Control replies ride the same bounded queue. A connection that
    // stopped reading gets its replies dropped after the timeout — the
    // dispatcher (or the EOF path below) tears it down.
    let reply = |j: Json| {
        let _ = send_with_timeout(&tx_conn, j.to_string(), opts.slow_reader_timeout);
    };

    // (client id, internal id) of every request this connection has in
    // flight — the cancel command's lookup table, and the set to
    // auto-cancel when the connection goes away. Pruned of finished
    // entries on every submission so it stays bounded by the in-flight
    // count, not the connection's lifetime history.
    let mut submitted: Vec<(u64, u64)> = Vec::new();

    let mut buf: Vec<u8> = Vec::new();
    loop {
        if ctl.dead.load(Ordering::Relaxed) {
            break; // declared dead by the dispatcher (slow reader)
        }
        match read_line_bounded(&mut reader, &mut buf, opts.max_line_bytes) {
            Ok(LineRead::Line) => {}
            Ok(LineRead::Eof) => break,
            Ok(LineRead::TooLong) => {
                // Cannot resync mid-line without buffering the rest of
                // it: report and close.
                reply(Json::obj(vec![(
                    "error",
                    Json::str(format!(
                        "line exceeds {} bytes; closing connection",
                        opts.max_line_bytes
                    )),
                )]));
                break;
            }
            Err(_) => break, // reset mid-read: treat as a disconnect
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(s) => s,
            Err(_) => {
                reply(Json::obj(vec![(
                    "error",
                    Json::str("line is not valid UTF-8"),
                )]));
                continue;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Ok(j) = Json::parse(&line) {
            match j.get("cmd").and_then(Json::as_str) {
                Some("stats") => {
                    // One engine-provided snapshot — the hit rate comes
                    // from the same counters the workers merged, not a
                    // hand-reassembled struct.
                    let pages = router.kv_page_stats();
                    let mut fields = vec![
                        ("workers", Json::num(router.num_workers() as f64)),
                        ("policy", Json::str(router.policy_name())),
                        ("kv_format", Json::str(router.kv_format())),
                        ("kv_policy", Json::str(router.kv_policy())),
                        (
                            "prefix_hit_tokens",
                            Json::num(router.prefix_hit_tokens() as f64),
                        ),
                        (
                            "kv_bytes_in_use",
                            Json::num(router.kv_bytes_in_use() as f64),
                        ),
                        ("decoded_page_hits", Json::num(pages.cache_hits as f64)),
                        ("decoded_page_misses", Json::num(pages.cache_misses as f64)),
                        ("decoded_page_hit_rate", Json::num(pages.cache_hit_rate())),
                    ];
                    // Stats v2.5: tiered KV memory — always present
                    // (mode "off" and zeros with --kv-spill off), so
                    // clients need no feature probe.
                    let tier = router.tier_stats();
                    fields.push((
                        "tier",
                        Json::obj(vec![
                            ("mode", Json::str(router.kv_spill_mode())),
                            ("hot_pages", Json::num(tier.hot_pages as f64)),
                            ("aged_pages", Json::num(tier.aged_pages as f64)),
                            ("spilled_pages", Json::num(tier.spilled_pages as f64)),
                            ("spilled_bytes", Json::num(tier.spilled_bytes as f64)),
                            ("pages_aged", Json::num(tier.pages_aged as f64)),
                            ("pages_spilled", Json::num(tier.pages_spilled as f64)),
                            ("pages_reloaded", Json::num(tier.pages_reloaded as f64)),
                            ("spill_bytes", Json::num(tier.spill_bytes as f64)),
                            ("reload_bytes", Json::num(tier.reload_bytes as f64)),
                        ]),
                    ));
                    // Stats v2: latency summaries + rolling gauges when
                    // the fleet runs with telemetry attached.
                    if let Some(t) = router.telemetry() {
                        let hist = |h: &crate::telemetry::Histogram| {
                            let s = h.snapshot();
                            Json::obj(vec![
                                ("count", Json::num(s.count as f64)),
                                ("p50_ms", Json::num(s.p50_us() as f64 / 1e3)),
                                ("p90_ms", Json::num(s.p90_us() as f64 / 1e3)),
                                ("p99_ms", Json::num(s.p99_us() as f64 / 1e3)),
                                ("mean_ms", Json::num(s.mean_us() / 1e3)),
                            ])
                        };
                        let now = t.now_sec();
                        fields.push(("ttft", hist(&t.ttft_us)));
                        fields.push(("inter_token", hist(&t.inter_token_us)));
                        fields.push(("decode_step", hist(&t.decode_step_us)));
                        fields.push(("queue", hist(&t.queue_us)));
                        fields.push((
                            "tokens_per_second_10s",
                            Json::num(t.tokens_10s.rate_per_sec(now)),
                        ));
                        fields.push(("ttft_ms_10s", Json::num(t.ttft_10s.mean(now) / 1e3)));
                        fields.push((
                            "requests_completed",
                            Json::num(t.requests_completed.get() as f64),
                        ));
                        fields.push((
                            "requests_cancelled",
                            Json::num(t.requests_cancelled.get() as f64),
                        ));
                        // Stats v2.3: the flat field above counts whole
                        // groups only; the nested object splits groups
                        // from individual candidates cancelled out of
                        // groups that kept running.
                        fields.push((
                            "cancelled",
                            Json::obj(vec![
                                (
                                    "groups",
                                    Json::num(t.requests_cancelled.get() as f64),
                                ),
                                (
                                    "candidates",
                                    Json::num(t.candidates_cancelled.get() as f64),
                                ),
                            ]),
                        ));
                        // Stats v2.3: speculative-decoding config +
                        // counters (mode "off" and zeros when disabled).
                        fields.push((
                            "spec",
                            Json::obj(vec![
                                ("mode", Json::str(router.spec_mode())),
                                ("k", Json::num(router.spec_k() as f64)),
                                (
                                    "rounds",
                                    Json::num(
                                        t.spec_tokens_per_round.snapshot().count as f64,
                                    ),
                                ),
                                (
                                    "proposed_tokens",
                                    Json::num(t.spec_proposed_tokens.get() as f64),
                                ),
                                (
                                    "accepted_tokens",
                                    Json::num(t.spec_accepted_tokens.get() as f64),
                                ),
                                (
                                    "rolled_back_tokens",
                                    Json::num(t.spec_rolled_back_tokens.get() as f64),
                                ),
                            ]),
                        ));
                        // Stats v2.4: resilience counters (worker
                        // supervision, load shedding, deadlines).
                        let deadline_cancels = t.deadline_cancels_request.get()
                            + t.deadline_cancels_queue.get()
                            + t.deadline_cancels_deadline.get();
                        fields.push((
                            "resilience",
                            Json::obj(vec![
                                (
                                    "worker_restarts",
                                    Json::num(router.restarts() as f64),
                                ),
                                (
                                    "requests_replayed",
                                    Json::num(t.requests_replayed.get() as f64),
                                ),
                                (
                                    "requests_shed",
                                    Json::num(t.requests_shed.get() as f64),
                                ),
                                (
                                    "deadline_cancels",
                                    Json::num(deadline_cancels as f64),
                                ),
                            ]),
                        ));
                    }
                    reply(Json::obj(fields));
                    continue;
                }
                Some("metrics") => {
                    match router.telemetry() {
                        Some(t) => {
                            let text = crate::telemetry::render_prometheus(
                                t,
                                &router.worker_gauges(),
                                &router.kv_page_stats(),
                            );
                            reply(Json::obj(vec![("metrics", Json::str(text))]));
                        }
                        None => {
                            reply(Json::obj(vec![(
                                "error",
                                Json::str("metrics: telemetry not attached"),
                            )]));
                        }
                    }
                    continue;
                }
                Some("cancel") => {
                    let target = j.get("id").and_then(Json::as_i64).map(|v| v as u64);
                    let cand = j.get("candidate").and_then(Json::as_usize);
                    // Latest *still-in-flight* submission under that
                    // client id wins — a finished request under a reused
                    // id must not shadow an older one still running.
                    let internal = target.and_then(|cid| {
                        let map = pending.lock().unwrap();
                        submitted
                            .iter()
                            .rev()
                            .find(|(c, i)| *c == cid && map.contains_key(i))
                            .map(|(_, i)| *i)
                    });
                    match internal {
                        Some(i) => {
                            // Fire and forget: the request's terminal
                            // line (finish: "cancelled") is the ack. A
                            // lost race against completion just means
                            // the normal summary already went out. With
                            // "candidate" only that candidate stops;
                            // the group's terminal line arrives when
                            // the last sibling finishes.
                            match cand {
                                Some(c) => {
                                    let _ = router.cancel_candidate(i, c);
                                }
                                None => {
                                    let _ = router.cancel(i);
                                }
                            }
                        }
                        None => {
                            reply(Json::obj(vec![(
                                "error",
                                Json::str("cancel: unknown id"),
                            )]));
                        }
                    }
                    continue;
                }
                Some(other) => {
                    reply(Json::obj(vec![(
                        "error",
                        Json::str(format!("unknown cmd {other:?}")),
                    )]));
                    continue;
                }
                None => {}
            }
        }
        let internal = next_id.fetch_add(1, Ordering::Relaxed);
        match parse_request(&line, internal) {
            Ok(parsed) => {
                {
                    let mut map = pending.lock().unwrap();
                    // Drop entries whose requests already finished.
                    submitted.retain(|(_, i)| map.contains_key(i));
                    map.insert(
                        internal,
                        PendingEntry {
                            client_id: parsed.client_id,
                            stream: parsed.stream,
                            logprobs: parsed.req.sampling.logprobs,
                            conn: conn_id,
                            ctl: ctl.clone(),
                            tx: tx_conn.clone(),
                        },
                    );
                }
                submitted.push((parsed.client_id, internal));
                if let Err(e) = router.submit(parsed.req) {
                    pending.lock().unwrap().remove(&internal);
                    reply(Json::obj(vec![("error", Json::str(e.to_string()))]));
                }
            }
            Err(msg) => {
                reply(Json::obj(vec![("error", Json::str(msg))]));
            }
        }
    }
    // Input closed (or the dispatcher declared us dead): cancel whatever
    // this connection still has in flight (finished ids are no longer
    // routable — those cancels are no-ops), then drop our sender; the
    // writer exits once the dispatcher has delivered (and dropped) every
    // remaining registration.
    for &(_, internal) in &submitted {
        if pending.lock().unwrap().contains_key(&internal) {
            let _ = router.cancel(internal);
        }
    }
    drop(tx_conn);
    let _ = writer_thread.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::coordinator::engine::EngineHandle;
    use crate::coordinator::router::Policy;
    use crate::coordinator::CandidateResult;
    use crate::runtime::host::HostBackend;
    use crate::runtime::ModelBackend;

    #[test]
    fn parse_request_full() {
        let p = parse_request(
            r#"{"id": 3, "tokens": [1, 2, 3], "max_new_tokens": 5, "dma": false,
                "temperature": 0.7, "top_k": 12, "top_p": 0.9, "seed": 11,
                "stop": [5, 9], "ignore_eos": true, "stream": true,
                "n": 2, "best_of": 4, "logprobs": true, "deadline_ms": 250}"#,
            99,
        )
        .unwrap();
        assert_eq!(p.req.id, 99); // internal id
        assert_eq!(p.client_id, 3); // echoed id
        assert_eq!(p.req.tokens, vec![1, 2, 3]);
        assert_eq!(p.req.max_new_tokens, 5);
        assert!(!p.req.dma);
        assert!((p.req.sampling.temperature - 0.7).abs() < 1e-6);
        assert_eq!(p.req.sampling.top_k, 12);
        assert!((p.req.sampling.top_p - 0.9).abs() < 1e-6);
        assert_eq!(p.req.sampling.seed, 11);
        assert_eq!(p.req.sampling.stop, vec![5, 9]);
        assert!(p.req.sampling.ignore_eos);
        assert_eq!(p.req.sampling.n, 2);
        assert_eq!(p.req.sampling.best_of, 4);
        assert!(p.req.sampling.logprobs);
        assert_eq!(p.req.sampling.deadline_ms, 250);
        assert!(p.stream);
    }

    #[test]
    fn parse_request_defaults() {
        let p = parse_request(r#"{"tokens": [4]}"#, 42).unwrap();
        assert_eq!(p.req.id, 42);
        assert_eq!(p.client_id, 42);
        assert_eq!(p.req.max_new_tokens, 16);
        assert!(p.req.dma);
        assert_eq!(p.req.sampling, SamplingParams::default());
        assert_eq!(p.req.sampling.n, 1);
        assert_eq!(p.req.sampling.best_of, 0);
        assert!(!p.req.sampling.logprobs);
        assert!(!p.stream);
    }

    #[test]
    fn parse_request_rejects_bad_json() {
        assert!(parse_request("{oops", 1).is_err());
        assert!(parse_request(r#"{"no_tokens": 1}"#, 1).is_err());
        assert!(parse_request(r#"{"tokens": [1], "stop": 5}"#, 1).is_err());
    }

    fn resp() -> Response {
        Response {
            id: 9,
            output: vec![1, 2],
            finish: crate::coordinator::FinishReason::Eos,
            candidates: vec![CandidateResult {
                candidate: 0,
                output: vec![1, 2],
                finish: crate::coordinator::FinishReason::Eos,
                cum_logprob: -1.5,
                logprobs: vec![-0.5, -1.0],
            }],
            queue_ms: 0.5,
            prefill_ms: 1.0,
            decode_ms: 2.0,
            ttft_ms: 1.5,
            error: None,
            retry_after_ms: None,
        }
    }

    #[test]
    fn response_round_trips_as_json() {
        let j = response_json(&resp(), false);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_i64(), Some(9));
        assert_eq!(parsed.get("finish").unwrap().as_str(), Some("eos"));
        assert_eq!(parsed.get("output").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(parsed.get("ttft_ms").unwrap().as_f64(), Some(1.5));
        // Non-streamed n=1 summary keeps the v2 shape exactly: no event
        // tag, no candidates array, no logprob fields.
        assert!(parsed.get("event").is_none());
        assert!(parsed.get("candidates").is_none());
        assert!(parsed.get("cum_logprob").is_none());
        assert!(parsed.get("logprobs").is_none());
    }

    #[test]
    fn response_json_groups_and_logprobs_are_additive() {
        // logprobs flag: per-token logprobs + cum for the best candidate.
        let j = response_json(&resp(), true);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("cum_logprob").unwrap().as_f64(), Some(-1.5));
        assert_eq!(parsed.get("logprobs").unwrap().as_arr().unwrap().len(), 2);
        assert!(parsed.get("candidates").is_none(), "n=1 has no candidates array");

        // A group summary carries every finalist.
        let mut r = resp();
        r.candidates.push(CandidateResult {
            candidate: 1,
            output: vec![1, 3],
            finish: crate::coordinator::FinishReason::Length,
            cum_logprob: -2.5,
            logprobs: vec![-0.5, -2.0],
        });
        let parsed = Json::parse(&response_json(&r, false).to_string()).unwrap();
        let cands = parsed.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].get("candidate").unwrap().as_i64(), Some(0));
        assert_eq!(cands[1].get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(cands[1].get("cum_logprob").unwrap().as_f64(), Some(-2.5));
        assert!(cands[0].get("logprobs").is_none(), "logprobs only when requested");
        let parsed = Json::parse(&response_json(&r, true).to_string()).unwrap();
        let cands = parsed.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands[1].get("logprobs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn event_lines_serialize() {
        let s = event_json(&EngineEvent::Started { id: 4, queue_ms: 0.25 }, true, false);
        let js = Json::parse(&s.to_string()).unwrap();
        assert_eq!(js.get("event").unwrap().as_str(), Some("started"));
        assert_eq!(js.get("id").unwrap().as_i64(), Some(4));

        let ev = EngineEvent::Token {
            id: 4,
            candidate: 2,
            token: 17,
            index: 2,
            logprob: -0.75,
            decode_ms: 0.5,
        };
        let t = event_json(&ev, true, false);
        let jt = Json::parse(&t.to_string()).unwrap();
        assert_eq!(jt.get("event").unwrap().as_str(), Some("token"));
        assert_eq!(jt.get("candidate").unwrap().as_i64(), Some(2));
        assert_eq!(jt.get("token").unwrap().as_i64(), Some(17));
        assert_eq!(jt.get("index").unwrap().as_i64(), Some(2));
        assert!(jt.get("logprob").is_none(), "logprob only when requested");
        let jt = Json::parse(&event_json(&ev, true, true).to_string()).unwrap();
        assert_eq!(jt.get("logprob").unwrap().as_f64(), Some(-0.75));

        let f = event_json(&EngineEvent::Finished(resp()), true, false);
        let jf = Json::parse(&f.to_string()).unwrap();
        assert_eq!(jf.get("event").unwrap().as_str(), Some("finished"));
        assert_eq!(jf.get("finish").unwrap().as_str(), Some("eos"));
    }

    fn spawn_server(
        cfg: EngineConfig,
        workers: usize,
        policy: Policy,
    ) -> (
        std::net::SocketAddr,
        Arc<AtomicBool>,
        std::thread::JoinHandle<()>,
    ) {
        let telemetry = Arc::new(crate::telemetry::Telemetry::new());
        let handles: Vec<EngineHandle> = (0..workers)
            .map(|i| {
                let c = cfg.clone();
                EngineHandle::spawn_with_telemetry(
                    || Ok(Box::new(HostBackend::for_tests()) as Box<dyn ModelBackend>),
                    c,
                    5,
                    telemetry.clone(),
                    i,
                )
            })
            .collect();
        let router = Arc::new(Router::with_telemetry(handles, policy, telemetry));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = std::sync::mpsc::channel();
        let stop2 = stop.clone();
        let srv = std::thread::spawn(move || {
            serve("127.0.0.1:0", router, stop2, move |a| {
                tx.send(a).unwrap();
            })
            .unwrap();
        });
        let addr = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        (addr, stop, srv)
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 3, ..Default::default() },
            1,
            Policy::RoundRobin,
        );

        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"cmd": "stats"}}"#).unwrap();
        writeln!(conn, r#"{{"id": 1, "tokens": [1, 9, 8, 7], "max_new_tokens": 2}}"#).unwrap();
        conn.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let s = Json::parse(line.trim()).unwrap();
        assert_eq!(s.get("workers").unwrap().as_i64(), Some(1));
        assert_eq!(s.get("policy").unwrap().as_str(), Some("round-robin"));
        assert_eq!(s.get("kv_format").unwrap().as_str(), Some("f32"));
        assert_eq!(s.get("kv_policy").unwrap().as_str(), Some("128/128"));
        assert_eq!(s.get("prefix_hit_tokens").unwrap().as_i64(), Some(0));
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64(), Some(1));
        assert!(j.get("output").unwrap().as_arr().unwrap().len() <= 2);
        // Non-streaming requests keep the v2 single-line shape.
        assert!(j.get("event").is_none());
        assert!(j.get("candidates").is_none());
        assert!(j.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn streaming_tokens_then_summary_and_cancel_over_tcp() {
        // The acceptance-bar e2e: a streamed request yields >= 1 token
        // line before its summary and replays the non-streamed output;
        // a second, long request is cancelled mid-flight and its KV pool
        // bytes return to the pre-admission count (via the stats cmd).
        // decode_slice 1: one token per scheduler step, so the cancel
        // sent after the first token line has dozens of steps of margin.
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 64, decode_slice: 1, ..Default::default() },
            1,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let read_json = |line: &mut String, reader: &mut BufReader<TcpStream>| {
            line.clear();
            reader.read_line(line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        // Idle pool bytes before any request.
        writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
        let bytes0 = read_json(&mut line, &mut reader)
            .get("kv_bytes_in_use")
            .unwrap()
            .as_i64()
            .unwrap();

        // 1. Non-streaming reference run (seeded sampling).
        writeln!(
            writer,
            "{}",
            concat!(
                r#"{"id": 1, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 6, "#,
                r#""temperature": 0.8, "seed": 21}"#
            )
        )
        .unwrap();
        let reference = read_json(&mut line, &mut reader);
        assert_eq!(reference.get("id").unwrap().as_i64(), Some(1));
        let ref_out: Vec<i64> = reference
            .get("output")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert!(!ref_out.is_empty());

        // 2. Same request streamed: token lines, then the summary, with
        //    an identical token sequence.
        writeln!(
            writer,
            "{}",
            concat!(
                r#"{"id": 2, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 6, "#,
                r#""temperature": 0.8, "seed": 21, "stream": true}"#
            )
        )
        .unwrap();
        let mut streamed_tokens: Vec<i64> = Vec::new();
        let mut saw_started = false;
        let summary = loop {
            let j = read_json(&mut line, &mut reader);
            assert_eq!(j.get("id").unwrap().as_i64(), Some(2));
            match j.get("event").unwrap().as_str().unwrap() {
                "started" => saw_started = true,
                "token" => {
                    assert_eq!(
                        j.get("index").unwrap().as_i64().unwrap(),
                        streamed_tokens.len() as i64
                    );
                    assert_eq!(j.get("candidate").unwrap().as_i64(), Some(0));
                    streamed_tokens.push(j.get("token").unwrap().as_i64().unwrap());
                }
                "finished" => break j,
                other => panic!("unexpected event {other}"),
            }
        };
        assert!(saw_started);
        assert!(!streamed_tokens.is_empty(), "no token line before the summary");
        assert_eq!(streamed_tokens, ref_out, "streamed run diverged from batch run");
        let sum_out: Vec<i64> = summary
            .get("output")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(sum_out, streamed_tokens);
        assert!(summary.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

        // 3. Long ignore_eos request, cancelled after its first token.
        writeln!(
            writer,
            "{}",
            concat!(
                r#"{"id": 3, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 60, "#,
                r#""ignore_eos": true, "stream": true}"#
            )
        )
        .unwrap();
        // Wait for the first token so the cancel lands mid-decode.
        loop {
            let j = read_json(&mut line, &mut reader);
            if j.get("event").unwrap().as_str() == Some("token") {
                break;
            }
        }
        writeln!(writer, r#"{{"cmd": "cancel", "id": 3}}"#).unwrap();
        let summary = loop {
            let j = read_json(&mut line, &mut reader);
            if j.get("event").unwrap().as_str() == Some("finished") {
                break j;
            }
        };
        assert_eq!(summary.get("finish").unwrap().as_str(), Some("cancelled"));
        let n_out = summary.get("output").unwrap().as_arr().unwrap().len();
        assert!(n_out >= 1 && n_out < 60, "cancel did not interrupt: {n_out}");

        // 4. Pool bytes return to the pre-admission count (the worker
        //    publishes the gauge after its next scheduler step).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
            let bytes = read_json(&mut line, &mut reader)
                .get("kv_bytes_in_use")
                .unwrap()
                .as_i64()
                .unwrap();
            if bytes == bytes0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "pool bytes never returned: {bytes} != {bytes0}"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }

        // 5. Cancel for an id this connection never sent is an error.
        writeln!(writer, r#"{{"cmd": "cancel", "id": 77}}"#).unwrap();
        let j = read_json(&mut line, &mut reader);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("unknown id"));

        // EOF the server's reader so the connection thread can exit.
        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn parallel_sampling_and_logprobs_over_tcp() {
        // decode_slice 1: one token per candidate per scheduler step, so
        // the candidate-cancel below lands with steps of margin.
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 32, decode_slice: 1, ..Default::default() },
            1,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let read_json = |line: &mut String, reader: &mut BufReader<TcpStream>| {
            line.clear();
            reader.read_line(line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        // Streamed n=2 with logprobs: token lines are candidate-tagged
        // and carry logprob; the summary reports both candidates.
        writeln!(
            writer,
            "{}",
            concat!(
                r#"{"id": 1, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 4, "#,
                r#""temperature": 0.8, "seed": 3, "n": 2, "logprobs": true, "#,
                r#""stream": true}"#
            )
        )
        .unwrap();
        let mut per_candidate: std::collections::HashMap<i64, Vec<i64>> =
            std::collections::HashMap::new();
        let summary = loop {
            let j = read_json(&mut line, &mut reader);
            match j.get("event").unwrap().as_str().unwrap() {
                "started" => {}
                "token" => {
                    let cand = j.get("candidate").unwrap().as_i64().unwrap();
                    let toks = per_candidate.entry(cand).or_default();
                    assert_eq!(j.get("index").unwrap().as_i64().unwrap(), toks.len() as i64);
                    let lp = j.get("logprob").unwrap().as_f64().unwrap();
                    assert!(lp <= 0.0 && lp.is_finite());
                    toks.push(j.get("token").unwrap().as_i64().unwrap());
                }
                "finished" => break j,
                other => panic!("unexpected event {other}"),
            }
        };
        assert_eq!(per_candidate.len(), 2, "both candidates streamed");
        let cands = summary.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        // Summary candidates replay the streamed per-candidate tokens.
        for c in cands {
            let idx = c.get("candidate").unwrap().as_i64().unwrap();
            let out: Vec<i64> = c
                .get("output")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap())
                .collect();
            assert_eq!(&out, &per_candidate[&idx], "candidate {idx}");
            assert!(c.get("cum_logprob").unwrap().as_f64().is_some());
            assert_eq!(
                c.get("logprobs").unwrap().as_arr().unwrap().len(),
                out.len()
            );
        }
        // Best-first ordering.
        assert!(
            cands[0].get("cum_logprob").unwrap().as_f64().unwrap()
                >= cands[1].get("cum_logprob").unwrap().as_f64().unwrap()
        );

        // Non-streaming greedy n=2: single summary line, candidates
        // identical, flat output mirrors candidate 0.
        writeln!(
            writer,
            r#"{{"id": 2, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 3, "n": 2}}"#
        )
        .unwrap();
        let j = read_json(&mut line, &mut reader);
        assert!(j.get("event").is_none());
        let cands = j.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        assert_eq!(
            cands[0].get("output").unwrap().as_arr().unwrap().len(),
            j.get("output").unwrap().as_arr().unwrap().len()
        );
        assert!(j.get("logprobs").is_none(), "logprobs not requested");

        // Candidate cancel: kill candidate 1 of a long group; the
        // summary still arrives with candidate 0 run to length.
        writeln!(
            writer,
            "{}",
            concat!(
                r#"{"id": 3, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 8, "#,
                r#""ignore_eos": true, "n": 2, "stream": true}"#
            )
        )
        .unwrap();
        loop {
            let j = read_json(&mut line, &mut reader);
            if j.get("event").unwrap().as_str() == Some("token") {
                break;
            }
        }
        writeln!(writer, r#"{{"cmd": "cancel", "id": 3, "candidate": 1}}"#).unwrap();
        let summary = loop {
            let j = read_json(&mut line, &mut reader);
            if j.get("event").unwrap().as_str() == Some("finished") {
                break j;
            }
        };
        let cands = summary.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        let finishes: Vec<&str> = cands
            .iter()
            .map(|c| c.get("finish").unwrap().as_str().unwrap())
            .collect();
        assert!(finishes.contains(&"cancelled"), "{finishes:?}");
        assert_eq!(summary.get("finish").unwrap().as_str(), Some("length"));

        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn slow_reader_is_abandoned_and_cancelled() {
        // Dispatcher-level back-pressure policy: a registration whose
        // bounded queue never drains is abandoned after the timeout —
        // its entries leave the registry, its connection is flagged
        // dead, and its in-flight requests are cancelled (KV released).
        let h = EngineHandle::spawn(
            || Ok(Box::new(HostBackend::for_tests()) as Box<dyn ModelBackend>),
            EngineConfig { max_new_tokens: 64, decode_slice: 1, ..Default::default() },
            5,
        );
        let router = Router::new(vec![h], Policy::RoundRobin);
        let pending: Pending = Arc::new(Mutex::new(HashMap::new()));
        let ctl = Arc::new(ConnCtl { dead: AtomicBool::new(false), sock: None });
        // Capacity-1 queue we never drain: the receiver is alive (so
        // sends see Full, not Disconnected) but nothing reads.
        let (tx, _rx) = mpsc::sync_channel::<String>(1);
        pending.lock().unwrap().insert(
            100,
            PendingEntry {
                client_id: 1,
                stream: true,
                logprobs: false,
                conn: 7,
                ctl: ctl.clone(),
                tx,
            },
        );
        router
            .submit(Request {
                id: 100,
                tokens: vec![1, 9, 8, 7],
                max_new_tokens: 60,
                dma: false,
                sampling: SamplingParams { ignore_eos: true, ..Default::default() },
            })
            .unwrap();
        // Drive the dispatcher body until the slow reader trips.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while !ctl.dead.load(Ordering::Relaxed) && std::time::Instant::now() < deadline {
            for ev in router.poll_events(16) {
                dispatch_event(ev, &pending, &router, Duration::from_millis(50));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(ctl.dead.load(Ordering::Relaxed), "slow reader never abandoned");
        assert!(pending.lock().unwrap().is_empty(), "registration not dropped");
        // The cancel propagated: the worker's KV gauge drains to zero.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            // Drain any leftover events (the terminal cancelled event
            // has no registration left and is dropped).
            for ev in router.poll_events(16) {
                dispatch_event(ev, &pending, &router, Duration::from_millis(10));
            }
            if router.kv_bytes_in_use() == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "slow-reader cancel never released KV: {} bytes",
                router.kv_bytes_in_use()
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        router.shutdown();
    }

    #[test]
    fn disconnect_cancels_in_flight_requests() {
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 64, ..Default::default() },
            1,
            Policy::RoundRobin,
        );

        {
            let conn = TcpStream::connect(addr).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = BufReader::new(conn);
            writeln!(
                writer,
                "{}",
                concat!(
                    r#"{"id": 1, "tokens": [1, 9, 8, 7], "max_new_tokens": 60, "#,
                    r#""ignore_eos": true, "stream": true}"#
                )
            )
            .unwrap();
            // Make sure the request is running, then vanish.
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("started"));
        } // both halves dropped: disconnect

        // The abandoned generation must be cancelled: a fresh connection
        // sees the pool bytes drain back to zero.
        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let bytes = Json::parse(line.trim())
                .unwrap()
                .get("kv_bytes_in_use")
                .unwrap()
                .as_i64()
                .unwrap();
            if bytes == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "disconnect did not cancel: {bytes} bytes still held"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }

        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn stats_expose_decoded_page_hit_rate() {
        // Quantized cache + a multi-token generation: steady-state decode
        // serves full pages from the decoded-page cache, and /stats must
        // surface the hit counters. threads > 1 exercises the fan-out
        // through the whole server stack.
        let (addr, stop, srv) = spawn_server(
            EngineConfig {
                max_new_tokens: 16,
                kv_format: crate::kvquant::KvFormat::Dual,
                kv_precision_policies: vec![crate::kvquant::KvPolicy { sink: 16, diag: 16 }],
                threads: 2,
                ..Default::default()
            },
            1,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        // A 40-token prompt fills full pages; 16 decode steps then re-read
        // them every token.
        let toks: Vec<String> =
            (0..40).map(|i| (((i * 7) % 58) + 6).to_string()).collect();
        writeln!(
            writer,
            r#"{{"id": 1, "tokens": [{}], "max_new_tokens": 16, "ignore_eos": true}}"#,
            toks.join(",")
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("output"), "{line}");
        line.clear();
        writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let s = Json::parse(line.trim()).unwrap();
        assert_eq!(s.get("kv_format").unwrap().as_str(), Some("dual"));
        let hits = s.get("decoded_page_hits").unwrap().as_i64().unwrap();
        let misses = s.get("decoded_page_misses").unwrap().as_i64().unwrap();
        let rate = s.get("decoded_page_hit_rate").unwrap().as_f64().unwrap();
        assert!(hits > 0, "no decoded-page hits after a 16-token decode");
        assert!(misses > 0, "cold pages must miss first");
        assert!(rate > 0.0 && rate <= 1.0, "rate {rate}");

        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn metrics_cmd_reflects_completed_request() {
        // A completed streamed request must be visible in both surfaces:
        // the Prometheus text (nonzero TTFT count, worker gauges) and
        // the stats v2 summaries, and the decoded-page counters of the
        // two surfaces must agree (one engine-provided snapshot).
        let (addr, stop, srv) = spawn_server(
            EngineConfig {
                max_new_tokens: 8,
                kv_format: crate::kvquant::KvFormat::Dual,
                kv_precision_policies: vec![crate::kvquant::KvPolicy { sink: 16, diag: 16 }],
                ..Default::default()
            },
            1,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let read_json = |line: &mut String, reader: &mut BufReader<TcpStream>| {
            line.clear();
            reader.read_line(line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        // Stream one request to completion (a 40-token prompt fills
        // quantized pages so the decoded-page counters move).
        let toks: Vec<String> =
            (0..40).map(|i| (((i * 7) % 58) + 6).to_string()).collect();
        writeln!(
            writer,
            r#"{{"id": 1, "tokens": [{}], "max_new_tokens": 8, "ignore_eos": true, "stream": true}}"#,
            toks.join(",")
        )
        .unwrap();
        let mut tokens = 0;
        loop {
            let j = read_json(&mut line, &mut reader);
            match j.get("event").unwrap().as_str().unwrap() {
                "token" => tokens += 1,
                "finished" => break,
                _ => {}
            }
        }
        assert!(tokens > 0);

        // The metrics reply is one JSON line whose "metrics" field holds
        // the Prometheus exposition text.
        writeln!(writer, r#"{{"cmd": "metrics"}}"#).unwrap();
        let j = read_json(&mut line, &mut reader);
        let text = j.get("metrics").unwrap().as_str().unwrap().to_string();
        for family in [
            "# TYPE dma_ttft_seconds histogram",
            "# TYPE dma_inter_token_seconds histogram",
            "# TYPE dma_decode_step_seconds histogram",
            "# TYPE dma_requests_completed_total counter",
            "# TYPE dma_worker_queue_depth gauge",
            "# TYPE dma_worker_kv_pressure gauge",
        ] {
            assert!(text.contains(family), "missing {family:?} in:\n{text}");
        }
        assert!(text.contains("dma_ttft_seconds_count 1"), "{text}");
        assert!(text.contains("dma_requests_completed_total 1"), "{text}");
        let cache_hits = text
            .lines()
            .find_map(|l| l.strip_prefix("dma_decoded_page_hits_total "))
            .expect("dma_decoded_page_hits_total sample")
            .parse::<u64>()
            .unwrap();

        // Stats v2 agrees with the exposition.
        writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
        let s = read_json(&mut line, &mut reader);
        assert_eq!(
            s.get("decoded_page_hits").unwrap().as_i64().unwrap() as u64,
            cache_hits,
            "stats and metrics disagree on decoded-page hits"
        );
        let ttft = s.get("ttft").unwrap();
        assert_eq!(ttft.get("count").unwrap().as_i64(), Some(1));
        assert!(ttft.get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(s.get("requests_completed").unwrap().as_i64(), Some(1));
        assert!(
            s.get("tokens_per_second_10s").unwrap().as_f64().unwrap() > 0.0,
            "rolling throughput gauge empty right after a decode"
        );
        // Stats v2.3: the cancelled split and the spec block are present
        // even with speculation off (mode "off", all counters zero), and
        // the spec metric families render all-zero in the exposition.
        let cancelled = s.get("cancelled").unwrap();
        assert_eq!(cancelled.get("groups").unwrap().as_i64(), Some(0));
        assert_eq!(cancelled.get("candidates").unwrap().as_i64(), Some(0));
        let spec = s.get("spec").unwrap();
        assert_eq!(spec.get("mode").unwrap().as_str(), Some("off"));
        assert_eq!(spec.get("rounds").unwrap().as_i64(), Some(0));
        assert_eq!(spec.get("proposed_tokens").unwrap().as_i64(), Some(0));
        assert_eq!(spec.get("accepted_tokens").unwrap().as_i64(), Some(0));
        assert_eq!(spec.get("rolled_back_tokens").unwrap().as_i64(), Some(0));
        assert!(text.contains("dma_spec_proposed_tokens_total 0"), "{text}");
        assert!(text.contains("# TYPE dma_spec_accepted_tokens histogram"), "{text}");
        // Stats v2.5: the tier block is always present (mode "off" and
        // zeros here — this server runs without --kv-spill), and the
        // tier families render all-zero in the exposition.
        let tier = s.get("tier").unwrap();
        assert_eq!(tier.get("mode").unwrap().as_str(), Some("off"));
        assert_eq!(tier.get("spilled_pages").unwrap().as_i64(), Some(0));
        assert_eq!(tier.get("pages_aged").unwrap().as_i64(), Some(0));
        assert_eq!(tier.get("spill_bytes").unwrap().as_i64(), Some(0));
        assert!(text.contains("dma_kv_spill_bytes_total 0"), "{text}");
        assert!(text.contains("dma_kv_tier_pages{tier=\"spilled\"} 0"), "{text}");

        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn spec_stats_report_acceptance_over_tcp() {
        // A periodic prompt makes the prompt-lookup proposer draft the
        // continuation; greedy decode then accepts multiple tokens per
        // round, which the v2.3 spec block and metric families report.
        let (addr, stop, srv) = spawn_server(
            EngineConfig {
                max_new_tokens: 16,
                spec: crate::spec::SpecMode::PromptLookup,
                spec_k: 4,
                ..Default::default()
            },
            1,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let toks: Vec<String> =
            (0..24).map(|i| ((i % 4) + 7).to_string()).collect();
        writeln!(
            writer,
            r#"{{"id": 1, "tokens": [{}], "max_new_tokens": 12, "ignore_eos": true}}"#,
            toks.join(",")
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("output").unwrap().as_arr().unwrap().len(), 12);

        writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let s = Json::parse(line.trim()).unwrap();
        let spec = s.get("spec").unwrap();
        assert_eq!(spec.get("mode").unwrap().as_str(), Some("prompt-lookup"));
        assert_eq!(spec.get("k").unwrap().as_i64(), Some(4));
        // 12 emitted tokens minus the prefill-boundary one: 11 decode
        // emissions over rounds that each emit at least one token.
        let rounds = spec.get("rounds").unwrap().as_i64().unwrap();
        assert!((1..=11).contains(&rounds), "rounds {rounds} out of range");
        let proposed = spec.get("proposed_tokens").unwrap().as_i64().unwrap();
        let accepted = spec.get("accepted_tokens").unwrap().as_i64().unwrap();
        let rolled = spec.get("rolled_back_tokens").unwrap().as_i64().unwrap();
        assert!(accepted <= proposed, "accepted {accepted} > proposed {proposed}");
        assert!(rolled <= proposed, "rolled back {rolled} > proposed {proposed}");
        // Each round emits its accepted prefix plus the sampled
        // correction/bonus token — except a final round cut short by
        // the length cap on a matched draft, which emits exactly its
        // accepted count. 11 decode emissions total, so:
        assert!(
            rounds + accepted == 11 || rounds + accepted == 12,
            "emission accounting broke: rounds {rounds} + accepted {accepted}"
        );

        writeln!(writer, r#"{{"cmd": "metrics"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        let text = j.get("metrics").unwrap().as_str().unwrap().to_string();
        let sample = |name: &str| -> u64 {
            text.lines()
                .find_map(|l| l.strip_prefix(name))
                .unwrap_or_else(|| panic!("missing {name} in:\n{text}"))
                .trim()
                .parse()
                .unwrap()
        };
        assert_eq!(sample("dma_spec_proposed_tokens_total ") as i64, proposed);
        assert_eq!(sample("dma_spec_accepted_tokens_total ") as i64, accepted);
        assert_eq!(sample("dma_spec_rolled_back_tokens_total ") as i64, rolled);
        assert_eq!(sample("dma_spec_accepted_tokens_count ") as i64, rounds);

        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn tcp_server_multiple_clients() {
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 3, ..Default::default() },
            1,
            Policy::RoundRobin,
        );

        let clients: Vec<std::thread::JoinHandle<()>> = (0..3)
            .map(|ci| {
                std::thread::spawn(move || {
                    let mut conn = TcpStream::connect(addr).unwrap();
                    writeln!(
                        conn,
                        r#"{{"id": {ci}, "tokens": [1, 9, 8, 7, 6], "max_new_tokens": 2}}"#
                    )
                    .unwrap();
                    conn.shutdown(std::net::Shutdown::Write).unwrap();
                    let mut line = String::new();
                    BufReader::new(conn).read_line(&mut line).unwrap();
                    let j = Json::parse(line.trim()).unwrap();
                    assert_eq!(j.get("id").unwrap().as_i64(), Some(ci));
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn restarted_and_retry_after_serialize() {
        // v2.4 wire shapes: the restart marker and the shed backoff hint.
        let ev = EngineEvent::Restarted { id: 5, replayed_tokens: 3 };
        let j = Json::parse(&event_json(&ev, true, false).to_string()).unwrap();
        assert_eq!(j.get("event").unwrap().as_str(), Some("restarted"));
        assert_eq!(j.get("id").unwrap().as_i64(), Some(5));
        assert_eq!(j.get("replayed_tokens").unwrap().as_i64(), Some(3));

        let mut r = resp();
        let j = Json::parse(&response_json(&r, false).to_string()).unwrap();
        assert!(j.get("retry_after_ms").is_none(), "hint only when shed");
        r.finish = crate::coordinator::FinishReason::Rejected;
        r.retry_after_ms = Some(750);
        let j = Json::parse(&response_json(&r, false).to_string()).unwrap();
        assert_eq!(j.get("finish").unwrap().as_str(), Some("rejected"));
        assert_eq!(j.get("retry_after_ms").unwrap().as_i64(), Some(750));
    }

    #[test]
    fn read_line_bounded_frames_caps_and_partial_frames() {
        use std::io::Cursor;
        let mut buf = Vec::new();

        // Two frames, then clean EOF.
        let mut r = Cursor::new(b"abc\ndef\n".to_vec());
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Line));
        assert_eq!(buf, b"abc");
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Line));
        assert_eq!(buf, b"def");
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Eof));

        // A mid-frame disconnect surfaces the partial line, then EOF.
        let mut r = Cursor::new(b"partial".to_vec());
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Line));
        assert_eq!(buf, b"partial");
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Eof));

        // An oversized line trips the cap without buffering its tail.
        let mut r = Cursor::new(vec![b'x'; 1000]);
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 64).unwrap(), LineRead::TooLong));
        assert!(buf.len() <= 64, "buffered {} bytes past the cap", buf.len());

        // A line exactly at the cap is still a valid line.
        let mut r = Cursor::new(b"abcd\n".to_vec());
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 4).unwrap(), LineRead::Line));
        assert_eq!(buf, b"abcd");
    }

    #[test]
    fn writer_queue_failpoint_reports_undeliverable() {
        let _x = crate::util::failpoint::exclusive();
        crate::util::failpoint::configure("writer_queue:error:1", 7).unwrap();
        let (tx, _rx) = mpsc::sync_channel::<String>(4);
        assert!(!send_with_timeout(&tx, "hi".into(), Duration::from_millis(5)));
        crate::util::failpoint::clear();
        assert!(send_with_timeout(&tx, "hi".into(), Duration::from_millis(5)));
    }

    #[test]
    fn writer_thread_exits_when_abandoned_client_never_reads() {
        // Regression: the writer used to drain its queue with a plain
        // blocking `recv`, so an abandoned connection kept its writer
        // thread alive for as long as any sender clone survived — and a
        // writer wedged in a blocking socket write to a client that
        // never reads was stuck until the kernel buffer drained (never).
        // ConnCtl::kill must reap it either way.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap(); // never read from
        let (sock, _) = listener.accept().unwrap();
        let ctl = Arc::new(ConnCtl {
            dead: AtomicBool::new(false),
            sock: sock.try_clone().ok(),
        });
        let (tx, rx) = mpsc::sync_channel::<String>(4);
        let wctl = ctl.clone();
        let writer = std::thread::spawn(move || writer_loop(rx, sock, &wctl));
        // Flood until the bounded queue jams behind the kernel socket
        // buffer (the peer never reads).
        let big = "x".repeat(64 * 1024);
        for _ in 0..256 {
            if !send_with_timeout(&tx, big.clone(), Duration::from_millis(1)) {
                break;
            }
        }
        ctl.kill();
        // The writer must exit promptly even though `tx` is still alive.
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::spawn(move || {
            let _ = writer.join();
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("writer thread leaked after abandon");
        drop(tx);
        drop(client);
    }

    #[test]
    fn hostile_lines_get_structured_errors_then_close() {
        // Invalid UTF-8 and malformed JSON get structured error replies
        // and the connection keeps working; an oversized line gets an
        // error and a clean close.
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 2, ..Default::default() },
            1,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let read_json = |line: &mut String, reader: &mut BufReader<TcpStream>| {
            line.clear();
            reader.read_line(line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        writer.write_all(b"\xff\xfe\n").unwrap();
        let j = read_json(&mut line, &mut reader);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("UTF-8"));

        writeln!(writer, "{{oops").unwrap();
        let j = read_json(&mut line, &mut reader);
        assert!(j.get("error").is_some(), "malformed JSON must error");

        // Still alive: a real request round-trips.
        writeln!(writer, r#"{{"id": 1, "tokens": [1, 9, 8], "max_new_tokens": 1}}"#).unwrap();
        let j = read_json(&mut line, &mut reader);
        assert_eq!(j.get("id").unwrap().as_i64(), Some(1));

        // Oversized line (the default cap is 1 MiB): error, then EOF.
        let big = vec![b'x'; (1 << 20) + 1024];
        writer.write_all(&big).unwrap();
        writer.flush().unwrap();
        let j = read_json(&mut line, &mut reader);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("exceeds"));
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "server must close");

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }

    #[test]
    fn server_survives_worker_crash_and_replays_stream() {
        // The acceptance-bar e2e at the TCP layer: with decode-step
        // panics injected, the server keeps serving, the client sees a
        // "restarted" marker, and the greedy stream it gets after the
        // splice is bit-identical to the fault-free run.
        let _x = crate::util::failpoint::exclusive();
        crate::util::failpoint::clear();
        let (addr, stop, srv) = spawn_server(
            EngineConfig { max_new_tokens: 8, decode_slice: 1, ..Default::default() },
            2,
            Policy::RoundRobin,
        );

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let read_json = |line: &mut String, reader: &mut BufReader<TcpStream>| {
            line.clear();
            reader.read_line(line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        // Fault-free baseline (greedy: output is a pure function of the
        // prompt, so the replayed run must reproduce it exactly).
        writeln!(
            writer,
            r#"{{"id": 1, "tokens": [3, 9, 4, 7, 6], "max_new_tokens": 6, "ignore_eos": true}}"#
        )
        .unwrap();
        let baseline: Vec<i64> = read_json(&mut line, &mut reader)
            .get("output")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(baseline.len(), 6);

        // Every decode step panics until the marker arrives.
        crate::util::failpoint::configure("decode_step:panic:1", 0xD1CE).unwrap();
        writeln!(
            writer,
            "{}",
            concat!(
                r#"{"id": 2, "tokens": [3, 9, 4, 7, 6], "max_new_tokens": 6, "#,
                r#""ignore_eos": true, "stream": true}"#
            )
        )
        .unwrap();
        let mut tokens: Vec<i64> = Vec::new();
        let mut saw_restarted = false;
        let summary = loop {
            let j = read_json(&mut line, &mut reader);
            match j.get("event").unwrap().as_str().unwrap() {
                "started" => {}
                "restarted" => {
                    saw_restarted = true;
                    // Let the replayed dispatch run to completion.
                    crate::util::failpoint::clear();
                }
                "token" => {
                    assert_eq!(
                        j.get("index").unwrap().as_i64().unwrap(),
                        tokens.len() as i64,
                        "token indices must stay gapless across the splice"
                    );
                    tokens.push(j.get("token").unwrap().as_i64().unwrap());
                }
                "finished" => break j,
                other => panic!("unexpected event {other}"),
            }
        };
        crate::util::failpoint::clear();
        assert!(saw_restarted, "worker crash never surfaced a restart marker");
        assert_eq!(tokens, baseline, "replayed stream diverged");
        let sum_out: Vec<i64> = summary
            .get("output")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(sum_out, baseline);

        // The supervision counters are visible on both surfaces.
        writeln!(writer, r#"{{"cmd": "metrics"}}"#).unwrap();
        let text = read_json(&mut line, &mut reader)
            .get("metrics")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let restarts = text
            .lines()
            .find_map(|l| l.strip_prefix("dma_worker_restarts_total "))
            .expect("dma_worker_restarts_total sample")
            .parse::<u64>()
            .unwrap();
        assert!(restarts >= 1, "no restart recorded: {restarts}");
        assert!(text.contains("dma_requests_replayed_total"), "{text}");
        assert!(text.contains("dma_worker_healthy{worker=\"0\"} 1"), "{text}");
        writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
        let s = read_json(&mut line, &mut reader);
        let res = s.get("resilience").unwrap();
        assert_eq!(
            res.get("worker_restarts").unwrap().as_i64().unwrap() as u64,
            restarts,
            "stats and metrics disagree on restarts"
        );
        assert!(res.get("requests_replayed").unwrap().as_i64().unwrap() >= 1);

        // Final pool recount is clean: every page released.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            writeln!(writer, r#"{{"cmd": "stats"}}"#).unwrap();
            let bytes = read_json(&mut line, &mut reader)
                .get("kv_bytes_in_use")
                .unwrap()
                .as_i64()
                .unwrap();
            if bytes == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "pool never drained after crash recovery: {bytes} bytes"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }

        writer.shutdown(std::net::Shutdown::Write).unwrap();
        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }
}
