//! TCP JSON-lines serving front end (std::net — tokio is not vendored).
//!
//! Protocol: one JSON object per line.
//!
//! ```text
//! -> {"id": 1, "tokens": [1,7,9], "max_new_tokens": 8, "dma": true}
//! <- {"id": 1, "output": [12, 5], "finish": "eos",
//!     "queue_ms": 0.1, "prefill_ms": 3.2, "decode_ms": 8.9}
//! -> {"cmd": "stats"}          (optional control message)
//! <- {"workers": 1, "kv_format": "f32", "kv_policy": "128/128",
//!     "prefix_hit_tokens": 0}
//! ```
//!
//! Responses are routed back to the connection that submitted them by an
//! internal request id (client-supplied ids are echoed but may collide
//! across connections): each accepted request registers a per-connection
//! channel with the dispatcher, which drains the engine workers and
//! forwards each completion to its owner.

use crate::coordinator::router::Router;
use crate::coordinator::{Request, Response};
use crate::util::json::Json;
use anyhow::Context;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

pub fn parse_request(line: &str, internal_id: u64) -> Result<(Request, u64), String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let tokens = j
        .get("tokens")
        .and_then(Json::as_arr)
        .ok_or("missing tokens")?
        .iter()
        .map(|v| v.as_i64().map(|x| x as i32))
        .collect::<Option<Vec<i32>>>()
        .ok_or("tokens must be integers")?;
    let client_id = j
        .get("id")
        .and_then(Json::as_i64)
        .map(|v| v as u64)
        .unwrap_or(internal_id);
    Ok((
        Request {
            id: internal_id,
            tokens,
            max_new_tokens: j
                .get("max_new_tokens")
                .and_then(Json::as_usize)
                .unwrap_or(16),
            dma: j.get("dma").and_then(Json::as_bool).unwrap_or(true),
        },
        client_id,
    ))
}

pub fn response_json(r: &Response) -> Json {
    let mut fields = vec![
        ("id", Json::num(r.id as f64)),
        (
            "output",
            Json::arr(r.output.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("finish", Json::str(r.finish.as_str())),
        ("queue_ms", Json::num(r.queue_ms)),
        ("prefill_ms", Json::num(r.prefill_ms)),
        ("decode_ms", Json::num(r.decode_ms)),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Json::obj(fields)
}

/// internal id -> (client id, connection's response channel).
type Pending = Arc<Mutex<HashMap<u64, (u64, mpsc::Sender<Response>)>>>;

/// Serve until `stop` is set. The bound address is reported through
/// `on_bind` (tests connect to an ephemeral port).
pub fn serve(
    addr: &str,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    on_bind: impl FnOnce(std::net::SocketAddr),
) -> crate::Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bind(listener.local_addr()?);

    let pending: Pending = Arc::new(Mutex::new(HashMap::new()));
    let next_id = Arc::new(AtomicU64::new(1));

    // Dispatcher: drain worker completions, route to owning connections.
    let dispatcher = {
        let router = router.clone();
        let pending = pending.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let got = router.poll_responses(64);
                if got.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    continue;
                }
                for mut resp in got {
                    if let Some((client_id, tx)) =
                        pending.lock().unwrap().remove(&resp.id)
                    {
                        resp.id = client_id;
                        let _ = tx.send(resp);
                    }
                }
            }
        })
    };

    let mut handles = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let router = router.clone();
                let pending = pending.clone();
                let next_id = next_id.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &router, &pending, &next_id) {
                        eprintln!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                let _ = dispatcher.join();
                return Err(e.into());
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let _ = dispatcher.join();
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    pending: &Pending,
    next_id: &AtomicU64,
) -> crate::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream.try_clone()?;
    let (tx_conn, rx_conn) = mpsc::channel::<Response>();

    // Writer half: deliver completions in arrival order until every
    // sender (reader + dispatcher-held registrations) is gone.
    let mut wstream = stream;
    let writer_thread = std::thread::spawn(move || {
        for resp in rx_conn {
            if writeln!(wstream, "{}", response_json(&resp)).is_err() {
                break;
            }
        }
    });

    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if let Ok(j) = Json::parse(&line) {
            if j.get("cmd").and_then(Json::as_str) == Some("stats") {
                let out = Json::obj(vec![
                    ("workers", Json::num(router.num_workers() as f64)),
                    ("kv_format", Json::str(router.kv_format())),
                    ("kv_policy", Json::str(router.kv_policy())),
                    (
                        "prefix_hit_tokens",
                        Json::num(router.prefix_hit_tokens() as f64),
                    ),
                ]);
                writeln!(writer, "{out}")?;
                continue;
            }
        }
        let internal = next_id.fetch_add(1, Ordering::Relaxed);
        match parse_request(&line, internal) {
            Ok((req, client_id)) => {
                pending
                    .lock()
                    .unwrap()
                    .insert(internal, (client_id, tx_conn.clone()));
                if let Err(e) = router.submit(req) {
                    pending.lock().unwrap().remove(&internal);
                    let out = Json::obj(vec![("error", Json::str(e.to_string()))]);
                    writeln!(writer, "{out}")?;
                }
            }
            Err(msg) => {
                let out = Json::obj(vec![("error", Json::str(msg))]);
                writeln!(writer, "{out}")?;
            }
        }
    }
    // Input closed: drop our sender; the writer exits once the
    // dispatcher has delivered (and dropped) every pending registration.
    drop(tx_conn);
    let _ = writer_thread.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::coordinator::engine::EngineHandle;
    use crate::coordinator::router::Policy;
    use crate::runtime::host::HostBackend;
    use crate::runtime::ModelBackend;

    #[test]
    fn parse_request_full() {
        let (r, client) = parse_request(
            r#"{"id": 3, "tokens": [1, 2, 3], "max_new_tokens": 5, "dma": false}"#,
            99,
        )
        .unwrap();
        assert_eq!(r.id, 99); // internal id
        assert_eq!(client, 3); // echoed id
        assert_eq!(r.tokens, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert!(!r.dma);
    }

    #[test]
    fn parse_request_defaults() {
        let (r, client) = parse_request(r#"{"tokens": [4]}"#, 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(client, 42);
        assert_eq!(r.max_new_tokens, 16);
        assert!(r.dma);
    }

    #[test]
    fn parse_request_rejects_bad_json() {
        assert!(parse_request("{oops", 1).is_err());
        assert!(parse_request(r#"{"no_tokens": 1}"#, 1).is_err());
    }

    #[test]
    fn response_round_trips_as_json() {
        let r = Response {
            id: 9,
            output: vec![1, 2],
            finish: crate::coordinator::FinishReason::Eos,
            queue_ms: 0.5,
            prefill_ms: 1.0,
            decode_ms: 2.0,
            error: None,
        };
        let j = response_json(&r);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_i64(), Some(9));
        assert_eq!(parsed.get("finish").unwrap().as_str(), Some("eos"));
        assert_eq!(parsed.get("output").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn end_to_end_over_tcp() {
        let worker = EngineHandle::spawn(
            || Ok(Box::new(HostBackend::for_tests()) as Box<dyn ModelBackend>),
            EngineConfig { max_new_tokens: 3, ..Default::default() },
            5,
        );
        let router = Arc::new(Router::new(vec![worker], Policy::RoundRobin));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = std::sync::mpsc::channel();
        let stop2 = stop.clone();
        let router2 = router.clone();
        let srv = std::thread::spawn(move || {
            serve("127.0.0.1:0", router2, stop2, move |a| {
                tx.send(a).unwrap();
            })
            .unwrap();
        });
        let addr = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();

        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"cmd": "stats"}}"#).unwrap();
        writeln!(conn, r#"{{"id": 1, "tokens": [1, 9, 8, 7], "max_new_tokens": 2}}"#).unwrap();
        conn.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let s = Json::parse(line.trim()).unwrap();
        assert_eq!(s.get("workers").unwrap().as_i64(), Some(1));
        assert_eq!(s.get("kv_format").unwrap().as_str(), Some("f32"));
        assert_eq!(s.get("kv_policy").unwrap().as_str(), Some("128/128"));
        assert_eq!(s.get("prefix_hit_tokens").unwrap().as_i64(), Some(0));
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64(), Some(1));
        assert!(j.get("output").unwrap().as_arr().unwrap().len() <= 2);

        stop.store(true, Ordering::Relaxed);
        srv.join().unwrap();
    }
}
