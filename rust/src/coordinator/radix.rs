//! Radix (token-trie) prefix cache of immutable quantized KV pages.
//!
//! Every edge of the trie is one *full* cache page worth of prompt
//! tokens (`page_tokens`, matching the [`crate::kvcache::BlockPool`]
//! block size); a node holds the quantized K/V pages produced for that
//! token range — one [`Arc`] page per (layer, kv head) — plus the pool
//! accounting id that keeps the page's admission block reserved while it
//! is resident.
//!
//! Because the chunked quantized prefill is cache-authoritative (chunk
//! attention reads the quantized prefix pages, see
//! [`crate::model::CpuModel::prefill_chunk_quant`]), a page's content is
//! a pure function of the prompt tokens before it: two prompts sharing a
//! prefix produce bit-identical pages for it, so handing a new request
//! the cached pages and prefilling only the suffix reproduces its
//! cold-start outputs token for token — while the MXFP page format makes
//! each retained token 3–6x cheaper than an f32 prefix cache would be.
//!
//! Sharing is pure [`Arc`] cloning, no payload copies: [`PrefixHit::seed`]
//! imports the hit pages into a fresh sequence slot via
//! [`crate::kvquant::QuantPagedKv::push_shared_page`] (the related
//! `QuantPagedKv::fork` is the whole-store sequence-fork primitive the
//! engine uses for parallel-sampling candidates — same pages,
//! copy-on-write frontier; both sharing mechanisms compose, so a
//! group's prefix-cache pages are pinned once per group).
//! Pool accounting is wired through
//! [`crate::kvcache::BlockPool::fork_block`] (donation: one admission
//! block per cached page, split out of the donor's table) and
//! [`BlockPool::fork`](crate::kvcache::BlockPool::fork) (each sharer pins
//! the node's block for its lifetime). Eviction is LRU over leaves and
//! only targets unpinned pages, so every eviction frees a block.

use crate::kvcache::SeqId;
use crate::kvquant::QuantSlotKv;
use crate::mxfp::fused::DualQuantized;
use std::collections::BTreeMap;
use std::sync::Arc;

/// `[layer][kv head]` page payload of one node.
type PagePlane = Vec<Vec<Arc<DualQuantized>>>;

struct Node {
    /// BlockPool accounting id holding this page's admission block.
    pool_id: SeqId,
    k: PagePlane,
    v: PagePlane,
    /// LRU stamp (monotonic clock; larger = touched more recently).
    stamp: u64,
    children: BTreeMap<Vec<i32>, Node>,
}

/// Result of a prefix lookup: everything the engine needs to seed a
/// sequence — shared token count, the pool ids to fork for the sequence's
/// lifetime, and the page arcs in prefix order.
pub struct PrefixHit {
    pub tokens: usize,
    pub pool_ids: Vec<SeqId>,
    /// `[page][layer][head]` key pages, prefix order.
    pub k: Vec<PagePlane>,
    /// `[page][layer][head]` value pages, prefix order.
    pub v: Vec<PagePlane>,
}

impl PrefixHit {
    pub fn empty() -> PrefixHit {
        PrefixHit { tokens: 0, pool_ids: Vec::new(), k: Vec::new(), v: Vec::new() }
    }

    /// Drop trailing pages until the shared length is a multiple of
    /// `granularity` (the engine's prefill chunk): resuming prefill at a
    /// chunk boundary keeps the warm run's chunk layout — and therefore
    /// its pages and tokens — identical to the cold run's.
    pub fn align_to(&mut self, granularity: usize, page_tokens: usize) {
        // A granularity that is not a whole number of pages would leave
        // `tokens` pointing past the retained page lists.
        assert!(
            granularity >= page_tokens && granularity % page_tokens == 0,
            "align granularity {granularity} must be a multiple of page size {page_tokens}"
        );
        let aligned = (self.tokens / granularity) * granularity;
        if aligned == self.tokens {
            return;
        }
        let pages = aligned / page_tokens;
        self.tokens = aligned;
        self.pool_ids.truncate(pages);
        self.k.truncate(pages);
        self.v.truncate(pages);
    }

    /// Seed a fresh quantized slot with the shared pages (zero-copy).
    pub fn seed(&self, slot: &mut QuantSlotKv) {
        for (pk, pv) in self.k.iter().zip(&self.v) {
            for (li, heads) in pk.iter().enumerate() {
                for (h, page) in heads.iter().enumerate() {
                    slot.k[li][h].push_shared_page(page.clone());
                    slot.v[li][h].push_shared_page(pv[li][h].clone());
                }
            }
        }
        slot.pos = self.tokens;
    }
}

pub struct RadixCache {
    page_tokens: usize,
    /// One trie per prefill attention mode (`[native, dma]`): page
    /// content is a function of the prompt tokens AND the attention mode
    /// (the DMA kernel's mixed-precision first chunk produces different
    /// hidden states than native), so cross-mode reuse would break the
    /// warm-run-equals-cold-run contract.
    roots: [BTreeMap<Vec<i32>, Node>; 2],
    clock: u64,
    pages: usize,
}

impl RadixCache {
    pub fn new(page_tokens: usize) -> RadixCache {
        RadixCache {
            page_tokens,
            roots: [BTreeMap::new(), BTreeMap::new()],
            clock: 0,
            pages: 0,
        }
    }

    /// Resident pages.
    pub fn len(&self) -> usize {
        self.pages
    }

    pub fn is_empty(&self) -> bool {
        self.pages == 0
    }

    /// Longest cached prefix of `prompt` under attention mode `dma`,
    /// capped at `max_tokens` (the engine caps at a prefill-chunk
    /// boundary strictly inside the prompt so chunk boundaries — and
    /// therefore outputs — match the cold-start run exactly). Matched
    /// nodes are LRU-touched.
    pub fn lookup(&mut self, prompt: &[i32], dma: bool, max_tokens: usize) -> PrefixHit {
        let pt = self.page_tokens;
        let mut hit = PrefixHit::empty();
        let mut level = &mut self.roots[dma as usize];
        for chunk in prompt.chunks_exact(pt) {
            if hit.tokens + pt > max_tokens {
                break;
            }
            let cur = level;
            let Some(node) = cur.get_mut(chunk) else { break };
            self.clock += 1;
            node.stamp = self.clock;
            hit.tokens += pt;
            hit.pool_ids.push(node.pool_id);
            hit.k.push(node.k.clone());
            hit.v.push(node.v.clone());
            level = &mut node.children;
        }
        hit
    }

    /// Insert the full pages of a freshly prefilled prompt. Pages already
    /// resident are LRU-touched; for each new page `register(page_index)`
    /// must reserve pool accounting and return its id (returning `None`
    /// stops the insertion — no capacity left for the cache). Returns the
    /// number of pages inserted.
    pub fn insert(
        &mut self,
        prompt: &[i32],
        dma: bool,
        slot: &QuantSlotKv,
        mut register: impl FnMut(usize) -> Option<SeqId>,
    ) -> usize {
        let pt = self.page_tokens;
        let mut inserted = 0;
        let mut level = &mut self.roots[dma as usize];
        for (j, chunk) in prompt.chunks_exact(pt).enumerate() {
            if j >= slot.k[0][0].n_full_pages() {
                break;
            }
            let cur = level;
            if !cur.contains_key(chunk) {
                let Some(pool_id) = register(j) else { break };
                let plane = |s: &[Vec<crate::kvquant::QuantPagedKv>]| -> PagePlane {
                    s.iter()
                        .map(|heads| heads.iter().map(|st| st.page_arc(j).clone()).collect())
                        .collect()
                };
                self.clock += 1;
                cur.insert(
                    chunk.to_vec(),
                    Node {
                        pool_id,
                        k: plane(&slot.k),
                        v: plane(&slot.v),
                        stamp: self.clock,
                        children: BTreeMap::new(),
                    },
                );
                self.pages += 1;
                inserted += 1;
            }
            let node = cur.get_mut(chunk).unwrap();
            self.clock += 1;
            node.stamp = self.clock;
            level = &mut node.children;
        }
        inserted
    }

    /// Evict the least-recently-used *leaf* page whose pool id passes
    /// `evictable` (the engine supplies "no running sequence still forks
    /// its block", so every eviction really frees a block), returning its
    /// pool id for the engine to release. `None` when nothing qualifies.
    ///
    /// The scan walks both tries (O(pages)); fine at this testbed's cache
    /// sizes — a stamp-ordered side index would make it O(log n) if the
    /// cache ever grows past that.
    pub fn evict_lru_leaf(&mut self, evictable: impl Fn(SeqId) -> bool) -> Option<SeqId> {
        fn min_leaf(
            level: &BTreeMap<Vec<i32>, Node>,
            evictable: &impl Fn(SeqId) -> bool,
        ) -> Option<(u64, Vec<Vec<i32>>)> {
            let mut best: Option<(u64, Vec<Vec<i32>>)> = None;
            for (key, node) in level {
                let cand = if node.children.is_empty() {
                    if evictable(node.pool_id) {
                        Some((node.stamp, vec![key.clone()]))
                    } else {
                        None
                    }
                } else {
                    min_leaf(&node.children, evictable).map(|(s, mut path)| {
                        path.insert(0, key.clone());
                        (s, path)
                    })
                };
                if let Some((s, path)) = cand {
                    let better = match &best {
                        None => true,
                        Some((bs, _)) => s < *bs,
                    };
                    if better {
                        best = Some((s, path));
                    }
                }
            }
            best
        }
        // Globally-LRU qualifying leaf across both mode tries.
        let (root_idx, path) = self
            .roots
            .iter()
            .enumerate()
            .filter_map(|(i, r)| min_leaf(r, &evictable).map(|(s, p)| (s, i, p)))
            .min_by_key(|&(s, _, _)| s)
            .map(|(_, i, p)| (i, p))?;
        let mut level = &mut self.roots[root_idx];
        for key in &path[..path.len() - 1] {
            level = &mut level.get_mut(key).unwrap().children;
        }
        let node = level.remove(path.last().unwrap()).unwrap();
        self.pages -= 1;
        Some(node.pool_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvquant::{KvFormat, KvPolicy, KvQuantConfig};
    use crate::util::rng::Rng;

    fn slot_with(tokens: usize, seed: u64) -> QuantSlotKv {
        let cfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 4,
            policies: vec![KvPolicy { sink: 4, diag: 4 }],
        };
        let mut s = QuantSlotKv::new(cfg, 2, 2, 32);
        let mut rng = Rng::new(seed);
        for li in 0..2 {
            for h in 0..2 {
                let rows: Vec<f32> =
                    (0..tokens * 32).map(|_| rng.normal() as f32).collect();
                s.k[li][h].append_rows(&rows);
                s.v[li][h].append_rows(&rows);
            }
        }
        s.pos = tokens;
        s
    }

    fn prompt(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 50) as i32 + 1).collect()
    }

    #[test]
    fn miss_then_hit_after_insert() {
        let mut c = RadixCache::new(4);
        let p = prompt(12);
        assert_eq!(c.lookup(&p, false, 64).tokens, 0);

        let slot = slot_with(12, 1);
        let mut next = 100u64;
        let n = c.insert(&p, false, &slot, |_| {
            next += 1;
            Some(next)
        });
        assert_eq!(n, 3);
        assert_eq!(c.len(), 3);

        let hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 12);
        assert_eq!(hit.pool_ids.len(), 3);
        // Payload pages are the very same Arcs the slot holds.
        assert!(Arc::ptr_eq(&hit.k[0][1][0], slot.k[1][0].page_arc(0)));
        assert!(Arc::ptr_eq(&hit.v[2][0][1], slot.v[0][1].page_arc(2)));

        // A prompt sharing only the first 8 tokens matches two pages.
        let mut p2 = prompt(12);
        p2[9] = 49;
        assert_eq!(c.lookup(&p2, false, 64).tokens, 8);
        // The cap truncates to whole pages.
        assert_eq!(c.lookup(&p, false, 9).tokens, 8);
        assert_eq!(c.lookup(&p, false, 3).tokens, 0);
    }

    #[test]
    fn seed_imports_shared_pages() {
        let mut c = RadixCache::new(4);
        let p = prompt(8);
        let slot = slot_with(8, 2);
        c.insert(&p, false, &slot, |j| Some(10 + j as u64));
        let hit = c.lookup(&p, false, 8);
        let cfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 4,
            policies: vec![KvPolicy { sink: 4, diag: 4 }],
        };
        let mut seeded = QuantSlotKv::new(cfg, 2, 2, 32);
        hit.seed(&mut seeded);
        assert_eq!(seeded.pos, 8);
        assert!(Arc::ptr_eq(seeded.k[1][1].page_arc(1), slot.k[1][1].page_arc(1)));
    }

    #[test]
    fn insert_dedupes_and_stops_on_capacity() {
        let mut c = RadixCache::new(4);
        let p = prompt(16);
        let slot = slot_with(16, 3);
        // Only the first two registrations succeed.
        let mut budget = 2;
        let n = c.insert(&p, false, &slot, |j| {
            if budget == 0 {
                None
            } else {
                budget -= 1;
                Some(20 + j as u64)
            }
        });
        assert_eq!(n, 2);
        // Re-insert with capacity: only the missing tail registers.
        let mut calls = Vec::new();
        let n = c.insert(&p, false, &slot, |j| {
            calls.push(j);
            Some(30 + j as u64)
        });
        assert_eq!(n, 2);
        assert_eq!(calls, vec![2, 3]);
        assert_eq!(c.lookup(&p, false, 64).tokens, 16);
    }

    #[test]
    fn attention_modes_do_not_share_pages() {
        // DMA-mode prefill produces different pages than native for the
        // same tokens, so the tries are disjoint per mode.
        let mut c = RadixCache::new(4);
        let p = prompt(8);
        c.insert(&p, false, &slot_with(8, 7), |j| Some(300 + j as u64));
        assert_eq!(c.lookup(&p, false, 64).tokens, 8);
        assert_eq!(c.lookup(&p, true, 64).tokens, 0, "cross-mode hit");
        c.insert(&p, true, &slot_with(8, 8), |j| Some(400 + j as u64));
        assert_eq!(c.lookup(&p, true, 64).tokens, 8);
        assert_eq!(c.len(), 4);
        // Eviction drains both tries.
        let mut freed = Vec::new();
        while let Some(id) = c.evict_lru_leaf(|_| true) {
            freed.push(id);
        }
        freed.sort_unstable();
        assert_eq!(freed, vec![300, 301, 400, 401]);
        assert!(c.is_empty());
    }

    #[test]
    fn hit_aligns_down_to_chunk_multiples() {
        let mut c = RadixCache::new(4);
        let p = prompt(20);
        c.insert(&p, false, &slot_with(20, 6), |j| Some(40 + j as u64));
        // 5 pages resident; a 8-token chunk granularity keeps 4 (16
        // tokens), dropping the trailing page.
        let mut hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 20);
        hit.align_to(8, 4);
        assert_eq!(hit.tokens, 16);
        assert_eq!(hit.pool_ids, vec![40, 41, 42, 43]);
        assert_eq!(hit.k.len(), 4);
        // Already aligned: untouched.
        let mut hit = c.lookup(&p, false, 16);
        hit.align_to(8, 4);
        assert_eq!(hit.tokens, 16);
    }

    #[test]
    fn lru_leaf_eviction_order() {
        let mut c = RadixCache::new(4);
        let a = prompt(8);
        let mut b = prompt(8);
        b[5] = 49; // shares page 0, diverges on page 1
        c.insert(&a, false, &slot_with(8, 4), |j| Some(100 + j as u64));
        c.insert(&b, false, &slot_with(8, 5), |j| Some(200 + j as u64));
        assert_eq!(c.len(), 3); // shared root page + two leaves

        // Touch a's path so b's leaf is the LRU leaf.
        c.lookup(&a, false, 64);
        assert_eq!(c.evict_lru_leaf(|_| true), Some(201));
        assert_eq!(c.lookup(&b, false, 64).tokens, 4);
        // Next LRU leaf is a's page 1, then the shared root page.
        assert_eq!(c.evict_lru_leaf(|_| true), Some(101));
        assert_eq!(c.evict_lru_leaf(|_| true), Some(100));
        assert_eq!(c.evict_lru_leaf(|_| true), None);
        assert!(c.is_empty());
        assert_eq!(c.lookup(&a, false, 64).tokens, 0);
    }
}
