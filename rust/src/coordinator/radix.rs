//! Radix (token-trie) prefix cache of immutable quantized KV pages.
//!
//! Every edge of the trie is one *full* cache page worth of prompt
//! tokens (`page_tokens`, matching the [`crate::kvcache::BlockPool`]
//! block size); a node holds the quantized K/V pages produced for that
//! token range — one [`Arc`] page per (layer, kv head) — plus the pool
//! accounting id that keeps the page's admission block reserved while it
//! is resident.
//!
//! Because the chunked quantized prefill is cache-authoritative (chunk
//! attention reads the quantized prefix pages, see
//! [`crate::model::CpuModel::prefill_chunk_quant`]), a page's content is
//! a pure function of the prompt tokens before it: two prompts sharing a
//! prefix produce bit-identical pages for it, so handing a new request
//! the cached pages and prefilling only the suffix reproduces its
//! cold-start outputs token for token — while the MXFP page format makes
//! each retained token 3–6x cheaper than an f32 prefix cache would be.
//!
//! Sharing is pure [`Arc`] cloning, no payload copies: [`PrefixHit::seed`]
//! imports the hit pages into a fresh sequence slot via
//! [`crate::kvquant::QuantPagedKv::push_shared_page`] (the related
//! `QuantPagedKv::fork` is the whole-store sequence-fork primitive the
//! engine uses for parallel-sampling candidates — same pages,
//! copy-on-write frontier; both sharing mechanisms compose, so a
//! group's prefix-cache pages are pinned once per group).
//! Pool accounting is wired through
//! [`crate::kvcache::BlockPool::fork_block`] (donation: one admission
//! block per cached page, split out of the donor's table) and
//! [`BlockPool::fork`](crate::kvcache::BlockPool::fork) (each sharer pins
//! the node's block for its lifetime). Eviction is LRU over leaves and
//! only targets unpinned pages, so every eviction frees a block.

use crate::kvcache::SeqId;
use crate::kvquant::tier::{age_page, decode_node, TierManager};
use crate::kvquant::{KvPolicy, QuantSlotKv};
use crate::mxfp::fused::DualQuantized;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// `[layer][kv head]` page payload of one node.
type PagePlane = Vec<Vec<Arc<DualQuantized>>>;

/// Tier residency of one node's planes ([`crate::kvquant::tier`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PageState {
    /// Planes resident with the store format's full plane set.
    Hot,
    /// Planes resident, precision-aged down to the low copy (outside
    /// each layer's sink window); bytes credited back to the pool.
    Aged,
    /// Planes on disk in the worker's spill file; `k`/`v` are empty and
    /// the node's pool block is released until a reload.
    Spilled,
}

struct Node {
    /// BlockPool accounting id holding this page's admission block
    /// (while resident; a spilled node keeps the id as its spill-index
    /// key and re-allocates under it on reload).
    pool_id: SeqId,
    k: PagePlane,
    v: PagePlane,
    /// LRU stamp (monotonic clock; larger = touched more recently).
    stamp: u64,
    /// Wall-clock last touch driving the aging schedule.
    touched: Instant,
    state: PageState,
    children: BTreeMap<Vec<i32>, Node>,
}

/// Result of a prefix lookup: everything the engine needs to seed a
/// sequence — shared token count, the pool ids to fork for the sequence's
/// lifetime, and the page arcs in prefix order.
pub struct PrefixHit {
    pub tokens: usize,
    pub pool_ids: Vec<SeqId>,
    /// `[page][layer][head]` key pages, prefix order.
    pub k: Vec<PagePlane>,
    /// `[page][layer][head]` value pages, prefix order.
    pub v: Vec<PagePlane>,
}

impl PrefixHit {
    pub fn empty() -> PrefixHit {
        PrefixHit { tokens: 0, pool_ids: Vec::new(), k: Vec::new(), v: Vec::new() }
    }

    /// Drop trailing pages until the shared length is a multiple of
    /// `granularity` (the engine's prefill chunk): resuming prefill at a
    /// chunk boundary keeps the warm run's chunk layout — and therefore
    /// its pages and tokens — identical to the cold run's.
    pub fn align_to(&mut self, granularity: usize, page_tokens: usize) {
        // A granularity that is not a whole number of pages would leave
        // `tokens` pointing past the retained page lists.
        assert!(
            granularity >= page_tokens && granularity % page_tokens == 0,
            "align granularity {granularity} must be a multiple of page size {page_tokens}"
        );
        let aligned = (self.tokens / granularity) * granularity;
        if aligned == self.tokens {
            return;
        }
        let pages = aligned / page_tokens;
        self.tokens = aligned;
        self.pool_ids.truncate(pages);
        self.k.truncate(pages);
        self.v.truncate(pages);
    }

    /// Seed a fresh quantized slot with the shared pages (zero-copy).
    pub fn seed(&self, slot: &mut QuantSlotKv) {
        for (pk, pv) in self.k.iter().zip(&self.v) {
            for (li, heads) in pk.iter().enumerate() {
                for (h, page) in heads.iter().enumerate() {
                    slot.k[li][h].push_shared_page(page.clone());
                    slot.v[li][h].push_shared_page(pv[li][h].clone());
                }
            }
        }
        slot.pos = self.tokens;
    }
}

pub struct RadixCache {
    page_tokens: usize,
    /// One trie per prefill attention mode (`[native, dma]`): page
    /// content is a function of the prompt tokens AND the attention mode
    /// (the DMA kernel's mixed-precision first chunk produces different
    /// hidden states than native), so cross-mode reuse would break the
    /// warm-run-equals-cold-run contract.
    roots: [BTreeMap<Vec<i32>, Node>; 2],
    clock: u64,
    /// Resident (hot + aged) pages; spilled nodes are not counted.
    pages: usize,
    /// Resident pages currently in the aged tier.
    aged: usize,
}

impl RadixCache {
    pub fn new(page_tokens: usize) -> RadixCache {
        RadixCache {
            page_tokens,
            roots: [BTreeMap::new(), BTreeMap::new()],
            clock: 0,
            pages: 0,
            aged: 0,
        }
    }

    /// Resident pages.
    pub fn len(&self) -> usize {
        self.pages
    }

    pub fn is_empty(&self) -> bool {
        self.pages == 0
    }

    /// Resident page split `(hot, aged)` for the tier gauges.
    pub fn tier_pages(&self) -> (u64, u64) {
        ((self.pages - self.aged) as u64, self.aged as u64)
    }

    /// Longest cached prefix of `prompt` under attention mode `dma`,
    /// capped at `max_tokens` (the engine caps at a prefill-chunk
    /// boundary strictly inside the prompt so chunk boundaries — and
    /// therefore outputs — match the cold-start run exactly). Matched
    /// nodes are LRU-touched.
    pub fn lookup(&mut self, prompt: &[i32], dma: bool, max_tokens: usize) -> PrefixHit {
        let pt = self.page_tokens;
        let mut hit = PrefixHit::empty();
        let mut level = &mut self.roots[dma as usize];
        for chunk in prompt.chunks_exact(pt) {
            if hit.tokens + pt > max_tokens {
                break;
            }
            let cur = level;
            let Some(node) = cur.get_mut(chunk) else { break };
            if node.state == PageState::Spilled {
                // Non-resident planes cannot be shared; the hit stops
                // here (the engine reloads spilled path nodes *before*
                // looking up, so this only triggers when a reload could
                // not re-admit the page — the suffix prefills normally).
                break;
            }
            self.clock += 1;
            node.stamp = self.clock;
            node.touched = Instant::now();
            hit.tokens += pt;
            hit.pool_ids.push(node.pool_id);
            hit.k.push(node.k.clone());
            hit.v.push(node.v.clone());
            level = &mut node.children;
        }
        hit
    }

    /// Insert the full pages of a freshly prefilled prompt. Pages already
    /// resident are LRU-touched; for each new page `register(page_index)`
    /// must reserve pool accounting and return its id (returning `None`
    /// stops the insertion — no capacity left for the cache). Returns the
    /// number of pages inserted.
    pub fn insert(
        &mut self,
        prompt: &[i32],
        dma: bool,
        slot: &QuantSlotKv,
        mut register: impl FnMut(usize) -> Option<SeqId>,
    ) -> usize {
        let pt = self.page_tokens;
        let mut inserted = 0;
        let mut level = &mut self.roots[dma as usize];
        for (j, chunk) in prompt.chunks_exact(pt).enumerate() {
            if j >= slot.k[0][0].n_full_pages() {
                break;
            }
            let cur = level;
            if !cur.contains_key(chunk) {
                let Some(pool_id) = register(j) else { break };
                let plane = |s: &[Vec<crate::kvquant::QuantPagedKv>]| -> PagePlane {
                    s.iter()
                        .map(|heads| heads.iter().map(|st| st.page_arc(j).clone()).collect())
                        .collect()
                };
                self.clock += 1;
                cur.insert(
                    chunk.to_vec(),
                    Node {
                        pool_id,
                        k: plane(&slot.k),
                        v: plane(&slot.v),
                        stamp: self.clock,
                        touched: Instant::now(),
                        state: PageState::Hot,
                        children: BTreeMap::new(),
                    },
                );
                self.pages += 1;
                inserted += 1;
            }
            let node = cur.get_mut(chunk).unwrap();
            if node.state == PageState::Spilled {
                // An existing-but-spilled node stays the authority for
                // this range; donating a duplicate under it would fork
                // the trie. Rehydration happens through `reload_path`.
                break;
            }
            self.clock += 1;
            node.stamp = self.clock;
            node.touched = Instant::now();
            level = &mut node.children;
        }
        inserted
    }

    /// Evict the least-recently-used *leaf* page whose pool id passes
    /// `evictable` (the engine supplies "no running sequence still forks
    /// its block", so every eviction really frees a block), returning its
    /// pool id for the engine to release. `None` when nothing qualifies.
    ///
    /// The scan walks both tries (O(pages)); fine at this testbed's cache
    /// sizes — a stamp-ordered side index would make it O(log n) if the
    /// cache ever grows past that.
    pub fn evict_lru_leaf(&mut self, evictable: impl Fn(SeqId) -> bool) -> Option<SeqId> {
        fn min_leaf(
            level: &BTreeMap<Vec<i32>, Node>,
            evictable: &impl Fn(SeqId) -> bool,
        ) -> Option<(u64, Vec<Vec<i32>>)> {
            let mut best: Option<(u64, Vec<Vec<i32>>)> = None;
            for (key, node) in level {
                let cand = if node.children.is_empty() {
                    // Spilled leaves hold no pool block; drop-eviction
                    // targets resident pages only.
                    if node.state != PageState::Spilled && evictable(node.pool_id) {
                        Some((node.stamp, vec![key.clone()]))
                    } else {
                        None
                    }
                } else {
                    min_leaf(&node.children, evictable).map(|(s, mut path)| {
                        path.insert(0, key.clone());
                        (s, path)
                    })
                };
                if let Some((s, path)) = cand {
                    let better = match &best {
                        None => true,
                        Some((bs, _)) => s < *bs,
                    };
                    if better {
                        best = Some((s, path));
                    }
                }
            }
            best
        }
        // Globally-LRU qualifying leaf across both mode tries.
        let (root_idx, path) = self
            .roots
            .iter()
            .enumerate()
            .filter_map(|(i, r)| min_leaf(r, &evictable).map(|(s, p)| (s, i, p)))
            .min_by_key(|&(s, _, _)| s)
            .map(|(_, i, p)| (i, p))?;
        let mut level = &mut self.roots[root_idx];
        for key in &path[..path.len() - 1] {
            level = &mut level.get_mut(key).unwrap().children;
        }
        let node = level.remove(path.last().unwrap()).unwrap();
        self.pages -= 1;
        if node.state == PageState::Aged {
            self.aged -= 1;
        }
        Some(node.pool_id)
    }

    /// Spill the least-recently-used *resident* page that passes
    /// `evictable` to the tier's spill file — the tiered replacement
    /// for [`Self::evict_lru_leaf`] under admission pressure. Any
    /// depth qualifies, not just leaves: spilling keeps the node in
    /// the trie (children, future hits, and the token key survive;
    /// only the planes move to disk), so structure is never orphaned.
    /// Returns the node's pool id for the engine to release — every
    /// successful spill frees one admission block. `None` when nothing
    /// resident qualifies or the spill write failed (the caller falls
    /// back to pure-drop eviction or defers the admission).
    pub fn spill_lru(
        &mut self,
        tier: &mut TierManager,
        evictable: impl Fn(SeqId) -> bool,
    ) -> Option<SeqId> {
        fn min_resident(
            level: &BTreeMap<Vec<i32>, Node>,
            evictable: &impl Fn(SeqId) -> bool,
        ) -> Option<(u64, Vec<Vec<i32>>)> {
            let mut best: Option<(u64, Vec<Vec<i32>>)> = None;
            for (key, node) in level {
                let mut cand = None;
                if node.state != PageState::Spilled && evictable(node.pool_id) {
                    cand = Some((node.stamp, vec![key.clone()]));
                }
                if let Some((s, mut path)) = min_resident(&node.children, evictable) {
                    if cand.as_ref().is_none_or(|&(bs, _)| s < bs) {
                        path.insert(0, key.clone());
                        cand = Some((s, path));
                    }
                }
                if let Some((s, path)) = cand {
                    if best.as_ref().is_none_or(|&(bs, _)| s < bs) {
                        best = Some((s, path));
                    }
                }
            }
            best
        }
        let (root_idx, path) = self
            .roots
            .iter()
            .enumerate()
            .filter_map(|(i, r)| min_resident(r, &evictable).map(|(s, p)| (s, i, p)))
            .min_by_key(|&(s, _, _)| s)
            .map(|(_, i, p)| (i, p))?;
        let mut level = &mut self.roots[root_idx];
        for key in &path[..path.len() - 1] {
            level = &mut level.get_mut(key).unwrap().children;
        }
        let node = level.get_mut(path.last().unwrap()).unwrap();
        if tier.spill(node.pool_id, &node.k, &node.v).is_err() {
            return None;
        }
        node.k = Vec::new();
        node.v = Vec::new();
        if node.state == PageState::Aged {
            self.aged -= 1;
        }
        node.state = PageState::Spilled;
        self.pages -= 1;
        Some(node.pool_id)
    }

    /// Reload every spilled node on `prompt`'s match path back into
    /// residency so the subsequent [`Self::lookup`] sees the whole
    /// prefix. For each spilled node, in path order, `alloc(pool_id)`
    /// must re-reserve its admission block under the same id (returning
    /// `false` stops the reload there — the surviving prefix still
    /// hits; the suffix prefills normally). The first touched node's
    /// record is decoded synchronously on the engine thread; the rest
    /// of the run's records are prefetched — read back in one serial
    /// I/O sweep, then decoded in parallel on the process worker pool
    /// (`util::pool`) — so a long spilled prefix reloads at pool
    /// parallelism instead of page-at-a-time. Returns
    /// `(pages_reloaded, bytes_read)`.
    ///
    /// A checksum mismatch panics: the spill file is this process's own
    /// write-back of immutable pages, so corruption means undefined
    /// logits, not a recoverable miss.
    pub fn reload_path(
        &mut self,
        prompt: &[i32],
        dma: bool,
        tier: &mut TierManager,
        threads: usize,
        mut alloc: impl FnMut(SeqId) -> bool,
        mut unalloc: impl FnMut(SeqId),
    ) -> (u64, u64) {
        struct Pending<'a> {
            id: SeqId,
            k: &'a mut PagePlane,
            v: &'a mut PagePlane,
            state: &'a mut PageState,
            bytes: Vec<u8>,
            checksum: u64,
        }
        let pt = self.page_tokens;
        // Serial sweep: collect each spilled path node's raw record.
        let mut pending: Vec<Pending> = Vec::new();
        let mut level = &mut self.roots[dma as usize];
        let mut bytes_read = 0u64;
        for chunk in prompt.chunks_exact(pt) {
            let cur = level;
            let Some(node) = cur.get_mut(chunk) else { break };
            let Node { pool_id, k, v, state, children, .. } = node;
            if *state == PageState::Spilled {
                if !alloc(*pool_id) {
                    break;
                }
                let (bytes, checksum) = match tier.take_spilled(*pool_id) {
                    Ok(r) => r,
                    Err(_) => {
                        // I/O failure: give the block back and stop the
                        // hit here; the record stays indexed on disk.
                        unalloc(*pool_id);
                        break;
                    }
                };
                bytes_read += bytes.len() as u64;
                pending.push(Pending { id: *pool_id, k, v, state, bytes, checksum });
            }
            level = children;
        }
        if pending.is_empty() {
            return (0, 0);
        }
        // First touch decodes synchronously; the rest of the run rides
        // the worker pool.
        let decode = |p: &mut Pending| {
            let (k, v) = decode_node(&p.bytes, p.checksum)
                .unwrap_or_else(|e| panic!("kv spill reload of page {}: {e}", p.id));
            *p.k = k;
            *p.v = v;
        };
        let (first, rest) = pending.split_at_mut(1);
        decode(&mut first[0]);
        crate::util::pool::par_items(rest, threads, decode);
        let reloaded = pending.len() as u64;
        for p in pending {
            // Fresh stamps/touch come from the lookup that follows.
            *p.state = PageState::Hot;
            self.pages += 1;
        }
        (reloaded, bytes_read)
    }

    /// One pass of the aging schedule `hot → aged → spilled` over every
    /// resident page: a page idle past `age` whose block no other
    /// sequence pins (`evictable`) drops its high-precision planes —
    /// except for layers whose [`KvPolicy`] sink window covers the
    /// page, the positions the paper's policy keeps high because they
    /// tolerate precision loss worst — and the saved bytes are credited
    /// back through `credit`. A page idle past `2 * age` in the aged
    /// tier spills to disk and `release(pool_id)` frees its block.
    /// Returns `(nodes_aged, nodes_spilled)` this pass.
    pub fn age_idle(
        &mut self,
        tier: &mut TierManager,
        age: Duration,
        policies: &[KvPolicy],
        evictable: &impl Fn(SeqId) -> bool,
        credit: &mut impl FnMut(SeqId, usize),
        release: &mut impl FnMut(SeqId),
    ) -> (u64, u64) {
        struct Walk<'a, E, C, R> {
            tier: &'a mut TierManager,
            age: Duration,
            now: Instant,
            pt: usize,
            policies: &'a [KvPolicy],
            evictable: &'a E,
            credit: &'a mut C,
            release: &'a mut R,
            aged_nodes: u64,
            spilled_nodes: u64,
            aged_delta: isize,
            resident_delta: isize,
        }
        fn visit<E: Fn(SeqId) -> bool, C: FnMut(SeqId, usize), R: FnMut(SeqId)>(
            level: &mut BTreeMap<Vec<i32>, Node>,
            depth: usize,
            w: &mut Walk<'_, E, C, R>,
        ) {
            for node in level.values_mut() {
                visit(&mut node.children, depth + 1, w);
                if node.state == PageState::Spilled
                    || w.now.duration_since(node.touched) < w.age
                    || !(w.evictable)(node.pool_id)
                {
                    continue;
                }
                match node.state {
                    PageState::Hot => {
                        // Drop the high planes of every layer whose sink
                        // window has moved past this page.
                        let default = KvPolicy::default();
                        let mut saved = 0usize;
                        for planes in [&mut node.k, &mut node.v] {
                            for (li, heads) in planes.iter_mut().enumerate() {
                                let pol = w
                                    .policies
                                    .get(li.min(w.policies.len().wrapping_sub(1)))
                                    .unwrap_or(&default);
                                if depth * w.pt < pol.sink {
                                    continue;
                                }
                                for page in heads.iter_mut() {
                                    if let Some((aged, bytes)) = age_page(page) {
                                        *page = aged;
                                        saved += bytes;
                                    }
                                }
                            }
                        }
                        if saved > 0 {
                            (w.credit)(node.pool_id, saved);
                        }
                        node.state = PageState::Aged;
                        w.tier.note_aged(1);
                        w.aged_nodes += 1;
                        w.aged_delta += 1;
                    }
                    PageState::Aged => {
                        if w.now.duration_since(node.touched) < w.age * 2 {
                            continue;
                        }
                        if w.tier.spill(node.pool_id, &node.k, &node.v).is_err() {
                            continue;
                        }
                        node.k = Vec::new();
                        node.v = Vec::new();
                        node.state = PageState::Spilled;
                        (w.release)(node.pool_id);
                        w.spilled_nodes += 1;
                        w.aged_delta -= 1;
                        w.resident_delta -= 1;
                    }
                    PageState::Spilled => unreachable!(),
                }
            }
        }
        let mut w = Walk {
            tier,
            age,
            now: Instant::now(),
            pt: self.page_tokens,
            policies,
            evictable,
            credit,
            release,
            aged_nodes: 0,
            spilled_nodes: 0,
            aged_delta: 0,
            resident_delta: 0,
        };
        for root in &mut self.roots {
            visit(root, 0, &mut w);
        }
        let (aged_nodes, spilled_nodes) = (w.aged_nodes, w.spilled_nodes);
        self.aged = (self.aged as isize + w.aged_delta) as usize;
        self.pages = (self.pages as isize + w.resident_delta) as usize;
        (aged_nodes, spilled_nodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvquant::{KvFormat, KvPolicy, KvQuantConfig};
    use crate::util::rng::Rng;

    fn slot_with(tokens: usize, seed: u64) -> QuantSlotKv {
        let cfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 4,
            policies: vec![KvPolicy { sink: 4, diag: 4 }],
        };
        let mut s = QuantSlotKv::new(cfg, 2, 2, 32);
        let mut rng = Rng::new(seed);
        for li in 0..2 {
            for h in 0..2 {
                let rows: Vec<f32> =
                    (0..tokens * 32).map(|_| rng.normal() as f32).collect();
                s.k[li][h].append_rows(&rows);
                s.v[li][h].append_rows(&rows);
            }
        }
        s.pos = tokens;
        s
    }

    fn prompt(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 50) as i32 + 1).collect()
    }

    #[test]
    fn miss_then_hit_after_insert() {
        let mut c = RadixCache::new(4);
        let p = prompt(12);
        assert_eq!(c.lookup(&p, false, 64).tokens, 0);

        let slot = slot_with(12, 1);
        let mut next = 100u64;
        let n = c.insert(&p, false, &slot, |_| {
            next += 1;
            Some(next)
        });
        assert_eq!(n, 3);
        assert_eq!(c.len(), 3);

        let hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 12);
        assert_eq!(hit.pool_ids.len(), 3);
        // Payload pages are the very same Arcs the slot holds.
        assert!(Arc::ptr_eq(&hit.k[0][1][0], slot.k[1][0].page_arc(0)));
        assert!(Arc::ptr_eq(&hit.v[2][0][1], slot.v[0][1].page_arc(2)));

        // A prompt sharing only the first 8 tokens matches two pages.
        let mut p2 = prompt(12);
        p2[9] = 49;
        assert_eq!(c.lookup(&p2, false, 64).tokens, 8);
        // The cap truncates to whole pages.
        assert_eq!(c.lookup(&p, false, 9).tokens, 8);
        assert_eq!(c.lookup(&p, false, 3).tokens, 0);
    }

    #[test]
    fn seed_imports_shared_pages() {
        let mut c = RadixCache::new(4);
        let p = prompt(8);
        let slot = slot_with(8, 2);
        c.insert(&p, false, &slot, |j| Some(10 + j as u64));
        let hit = c.lookup(&p, false, 8);
        let cfg = KvQuantConfig {
            format: KvFormat::Dual,
            page_tokens: 4,
            policies: vec![KvPolicy { sink: 4, diag: 4 }],
        };
        let mut seeded = QuantSlotKv::new(cfg, 2, 2, 32);
        hit.seed(&mut seeded);
        assert_eq!(seeded.pos, 8);
        assert!(Arc::ptr_eq(seeded.k[1][1].page_arc(1), slot.k[1][1].page_arc(1)));
    }

    #[test]
    fn insert_dedupes_and_stops_on_capacity() {
        let mut c = RadixCache::new(4);
        let p = prompt(16);
        let slot = slot_with(16, 3);
        // Only the first two registrations succeed.
        let mut budget = 2;
        let n = c.insert(&p, false, &slot, |j| {
            if budget == 0 {
                None
            } else {
                budget -= 1;
                Some(20 + j as u64)
            }
        });
        assert_eq!(n, 2);
        // Re-insert with capacity: only the missing tail registers.
        let mut calls = Vec::new();
        let n = c.insert(&p, false, &slot, |j| {
            calls.push(j);
            Some(30 + j as u64)
        });
        assert_eq!(n, 2);
        assert_eq!(calls, vec![2, 3]);
        assert_eq!(c.lookup(&p, false, 64).tokens, 16);
    }

    #[test]
    fn attention_modes_do_not_share_pages() {
        // DMA-mode prefill produces different pages than native for the
        // same tokens, so the tries are disjoint per mode.
        let mut c = RadixCache::new(4);
        let p = prompt(8);
        c.insert(&p, false, &slot_with(8, 7), |j| Some(300 + j as u64));
        assert_eq!(c.lookup(&p, false, 64).tokens, 8);
        assert_eq!(c.lookup(&p, true, 64).tokens, 0, "cross-mode hit");
        c.insert(&p, true, &slot_with(8, 8), |j| Some(400 + j as u64));
        assert_eq!(c.lookup(&p, true, 64).tokens, 8);
        assert_eq!(c.len(), 4);
        // Eviction drains both tries.
        let mut freed = Vec::new();
        while let Some(id) = c.evict_lru_leaf(|_| true) {
            freed.push(id);
        }
        freed.sort_unstable();
        assert_eq!(freed, vec![300, 301, 400, 401]);
        assert!(c.is_empty());
    }

    #[test]
    fn hit_aligns_down_to_chunk_multiples() {
        let mut c = RadixCache::new(4);
        let p = prompt(20);
        c.insert(&p, false, &slot_with(20, 6), |j| Some(40 + j as u64));
        // 5 pages resident; a 8-token chunk granularity keeps 4 (16
        // tokens), dropping the trailing page.
        let mut hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 20);
        hit.align_to(8, 4);
        assert_eq!(hit.tokens, 16);
        assert_eq!(hit.pool_ids, vec![40, 41, 42, 43]);
        assert_eq!(hit.k.len(), 4);
        // Already aligned: untouched.
        let mut hit = c.lookup(&p, false, 16);
        hit.align_to(8, 4);
        assert_eq!(hit.tokens, 16);
    }

    #[test]
    fn lru_leaf_eviction_order() {
        let mut c = RadixCache::new(4);
        let a = prompt(8);
        let mut b = prompt(8);
        b[5] = 49; // shares page 0, diverges on page 1
        c.insert(&a, false, &slot_with(8, 4), |j| Some(100 + j as u64));
        c.insert(&b, false, &slot_with(8, 5), |j| Some(200 + j as u64));
        assert_eq!(c.len(), 3); // shared root page + two leaves

        // Touch a's path so b's leaf is the LRU leaf.
        c.lookup(&a, false, 64);
        assert_eq!(c.evict_lru_leaf(|_| true), Some(201));
        assert_eq!(c.lookup(&b, false, 64).tokens, 4);
        // Next LRU leaf is a's page 1, then the shared root page.
        assert_eq!(c.evict_lru_leaf(|_| true), Some(101));
        assert_eq!(c.evict_lru_leaf(|_| true), Some(100));
        assert_eq!(c.evict_lru_leaf(|_| true), None);
        assert!(c.is_empty());
        assert_eq!(c.lookup(&a, false, 64).tokens, 0);
    }

    fn tier(dir: &crate::util::spill::TempDir) -> TierManager {
        TierManager::new(crate::kvquant::tier::TierMode::Aging, dir.path()).unwrap()
    }

    #[test]
    fn spill_then_reload_restores_hit_bit_exact() {
        let dir = crate::util::spill::TempDir::new("dma_radix_tier").unwrap();
        let mut t = tier(&dir);
        let mut c = RadixCache::new(4);
        let p = prompt(12);
        let slot = slot_with(12, 9);
        c.insert(&p, false, &slot, |j| Some(500 + j as u64));
        assert_eq!(c.len(), 3);

        // LRU resident page is the path root (last-touch order).
        assert_eq!(c.spill_lru(&mut t, |_| true), Some(500));
        assert_eq!((c.len(), t.spilled_pages()), (2, 1));
        // The hit stops at the spilled root page.
        assert_eq!(c.lookup(&p, false, 64).tokens, 0);
        // Insert over the spilled range donates nothing (the spilled
        // node stays the authority for its range).
        assert_eq!(c.insert(&p, false, &slot, |j| Some(900 + j as u64)), 0);

        // Reload the path: the block re-reserves under the same id and
        // the planes come back bit-exact.
        let mut allocs = Vec::new();
        let (n, bytes) = c.reload_path(
            &p,
            false,
            &mut t,
            1,
            |id| {
                allocs.push(id);
                true
            },
            |_| (),
        );
        assert_eq!((n, allocs), (1, vec![500]));
        assert!(bytes > 0);
        assert_eq!(t.spilled_pages(), 0);
        let hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 12);
        assert_eq!(c.len(), 3);
        for (j, pk) in hit.k.iter().enumerate() {
            for li in 0..2 {
                for h in 0..2 {
                    let orig = slot.k[li][h].page_arc(j);
                    assert_eq!(pk[li][h].packed_fp4, orig.packed_fp4);
                    assert_eq!(pk[li][h].fp8_codes, orig.fp8_codes);
                    assert_eq!(pk[li][h].sq, orig.sq);
                }
            }
        }
    }

    #[test]
    fn spill_order_follows_last_touch_and_alloc_failure_stops_reload() {
        let dir = crate::util::spill::TempDir::new("dma_radix_tier").unwrap();
        let mut t = tier(&dir);
        let mut c = RadixCache::new(4);
        let p = prompt(12);
        c.insert(&p, false, &slot_with(12, 19), |j| Some(700 + j as u64));
        c.lookup(&p, false, 64); // re-touch the whole path in order
        assert_eq!(c.spill_lru(&mut t, |_| true), Some(700));
        assert_eq!(c.spill_lru(&mut t, |_| true), Some(701));
        assert_eq!(c.spill_lru(&mut t, |_| true), Some(702));
        assert_eq!(c.spill_lru(&mut t, |_| true), None);
        assert_eq!((c.len(), t.spilled_pages()), (0, 3));

        // Only the first two reload allocations succeed: the third page
        // stays spilled and the hit covers the reloaded prefix only.
        let mut budget = 2;
        let (n, _) = c.reload_path(
            &p,
            false,
            &mut t,
            1,
            |_| {
                if budget == 0 {
                    return false;
                }
                budget -= 1;
                true
            },
            |_| (),
        );
        assert_eq!(n, 2);
        assert_eq!(t.spilled_pages(), 1);
        assert_eq!(c.lookup(&p, false, 64).tokens, 8);
    }

    #[test]
    fn age_idle_respects_sink_window_then_spills() {
        let dir = crate::util::spill::TempDir::new("dma_radix_tier").unwrap();
        let mut t = tier(&dir);
        let mut c = RadixCache::new(4);
        let p = prompt(8);
        let slot = slot_with(8, 10);
        c.insert(&p, false, &slot, |j| Some(600 + j as u64));
        let policies = vec![KvPolicy { sink: 4, diag: 4 }];

        let mut credits = Vec::new();
        let mut released = Vec::new();
        let (aged, spilled) = c.age_idle(
            &mut t,
            Duration::ZERO,
            &policies,
            &|_| true,
            &mut |id, b| credits.push((id, b)),
            &mut |id| released.push(id),
        );
        assert_eq!((aged, spilled), (2, 0));
        assert_eq!(c.tier_pages(), (0, 2));
        // Page 0 sits inside the sink window (sink = 4 tokens = 1 page):
        // its planes stay high, so only page 1 credits bytes back.
        assert_eq!(credits.len(), 1);
        assert_eq!(credits[0].0, 601);
        assert!(credits[0].1 > 0);
        let hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 8);
        assert!(!hit.k[0][0][0].fp8_codes.is_empty(), "sink page kept high");
        assert!(hit.k[1][1][1].fp8_codes.is_empty(), "body page aged to low");
        assert!(!hit.k[1][1][1].packed_fp4.is_empty());

        // Second pass: aged pages past 2x the idle threshold spill and
        // release their blocks.
        let (aged, spilled) = c.age_idle(
            &mut t,
            Duration::ZERO,
            &policies,
            &|_| true,
            &mut |_, _| (),
            &mut |id| released.push(id),
        );
        assert_eq!((aged, spilled), (0, 2));
        assert_eq!(c.tier_pages(), (0, 0));
        assert!(c.is_empty());
        released.sort_unstable();
        assert_eq!(released, vec![600, 601]);
        assert_eq!(t.spilled_pages(), 2);

        // Reload brings the whole prefix back; the aged page returns in
        // its aged (low-only) form — spill is bit-exact per tier.
        let (n, _) = c.reload_path(&p, false, &mut t, 2, |_| true, |_| ());
        assert_eq!(n, 2);
        let hit = c.lookup(&p, false, 64);
        assert_eq!(hit.tokens, 8);
        assert!(!hit.k[0][0][0].fp8_codes.is_empty());
        assert!(hit.k[1][0][1].fp8_codes.is_empty());
    }

    #[test]
    fn pinned_pages_never_age_or_spill() {
        let dir = crate::util::spill::TempDir::new("dma_radix_tier").unwrap();
        let mut t = tier(&dir);
        let mut c = RadixCache::new(4);
        let p = prompt(8);
        c.insert(&p, false, &slot_with(8, 11), |j| Some(800 + j as u64));
        // The engine's evictable closure says page 800 is pinned.
        let (aged, spilled) = c.age_idle(
            &mut t,
            Duration::ZERO,
            &[KvPolicy { sink: 0, diag: 0 }],
            &|id| id != 800,
            &mut |_, _| (),
            &mut |_| (),
        );
        assert_eq!((aged, spilled), (1, 0));
        assert_eq!(c.spill_lru(&mut t, |id| id != 800), Some(801));
        assert_eq!(c.spill_lru(&mut t, |id| id != 800), None);
        assert_eq!(c.tier_pages(), (1, 0));
    }
}
