//! Request/response types and the request state machine.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    /// Use the DMA (mixed-precision) prefill path.
    pub dma: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated the EOS token.
    Eos,
    /// Hit the per-request new-token limit.
    Length,
    /// Hit the engine cache capacity.
    CacheFull,
    /// Rejected at admission (queue full / prompt too long).
    Rejected,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Length => "length",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Rejected => "rejected",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Vec<i32>,
    pub finish: FinishReason,
    /// Wall-clock milliseconds spent queued before prefill.
    pub queue_ms: f64,
    /// Prefill latency (ms).
    pub prefill_ms: f64,
    /// Total decode time (ms) across all generated tokens.
    pub decode_ms: f64,
    /// Error detail when rejected.
    pub error: Option<String>,
}

/// Engine-internal per-request tracking.
#[derive(Debug)]
pub(crate) enum SeqPhase {
    Queued,
    /// Chunked prefill in flight: `done_tokens` prompt tokens processed
    /// so far (including any prefix-cache hit that skipped real work).
    Prefilling { done_tokens: usize },
    Decoding,
}

#[derive(Debug)]
pub(crate) struct Tracked {
    pub req: Request,
    pub phase: SeqPhase,
    pub output: Vec<i32>,
    pub enqueued: Instant,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub queue_ms: f64,
    /// Next token to feed at the coming decode step.
    pub next_token: i32,
}

impl Tracked {
    pub fn new(req: Request) -> Tracked {
        Tracked {
            req,
            phase: SeqPhase::Queued,
            output: Vec::new(),
            enqueued: Instant::now(),
            prefill_ms: 0.0,
            decode_ms: 0.0,
            queue_ms: 0.0,
            next_token: 0,
        }
    }

    pub fn respond(&self, finish: FinishReason) -> Response {
        Response {
            id: self.req.id,
            output: self.output.clone(),
            finish,
            queue_ms: self.queue_ms,
            prefill_ms: self.prefill_ms,
            decode_ms: self.decode_ms,
            error: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Eos.as_str(), "eos");
        assert_eq!(FinishReason::Length.as_str(), "length");
    }

    #[test]
    fn tracked_responds_with_metrics() {
        let t = Tracked {
            req: Request { id: 7, tokens: vec![1], max_new_tokens: 4, dma: true },
            phase: SeqPhase::Decoding,
            output: vec![9, 8],
            enqueued: Instant::now(),
            prefill_ms: 1.5,
            decode_ms: 3.0,
            queue_ms: 0.5,
            next_token: 8,
        };
        let r = t.respond(FinishReason::Length);
        assert_eq!(r.id, 7);
        assert_eq!(r.output, vec![9, 8]);
        assert_eq!(r.finish, FinishReason::Length);
        assert!(r.prefill_ms > 0.0);
    }
}
