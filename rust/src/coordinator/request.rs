//! Request/response types, sampling parameters, the incremental
//! [`EngineEvent`] stream, and the request state machine.
//!
//! The serving contract is event-based: the engine emits `Started` when
//! a request is admitted, one `Token` per generated token, and a
//! terminal `Finished` carrying the assembled [`Response`] — so clients
//! can stream tokens and measure TTFT, while batch callers keep
//! consuming the back-compat `Response` built from the same events.

use super::sampling::Sampler;
use std::time::Instant;

/// Per-request decoding controls. `temperature == 0` (the default)
/// selects greedy argmax; otherwise sampling is fully deterministic
/// given `seed` — the per-request sampler owns its own RNG stream, so
/// batch composition and scheduling cannot change a request's tokens.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; 0 means greedy (argmax).
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling (0 = all).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability mass >= `top_p`
    /// (1.0 = off).
    pub top_p: f32,
    /// Seed of the request's private RNG stream.
    pub seed: u64,
    /// Generation stops when any of these token ids is produced
    /// (the stop token is included in the output, like EOS).
    pub stop: Vec<i32>,
    /// Keep generating past the EOS token (benchmarks, fixed-length
    /// probes).
    pub ignore_eos: bool,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop: Vec::new(),
            ignore_eos: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    /// Use the DMA (mixed-precision) prefill path.
    pub dma: bool,
    pub sampling: SamplingParams,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            id: 0,
            tokens: Vec::new(),
            max_new_tokens: 16,
            dma: true,
            sampling: SamplingParams::default(),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated the EOS token.
    Eos,
    /// Generated one of the request's stop tokens.
    Stop,
    /// Hit the per-request new-token limit.
    Length,
    /// Hit the engine cache capacity.
    CacheFull,
    /// Rejected at admission (queue full / prompt too long).
    Rejected,
    /// Cancelled by the client (or its connection going away).
    Cancelled,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Rejected => "rejected",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Vec<i32>,
    pub finish: FinishReason,
    /// Wall-clock milliseconds spent queued before prefill.
    pub queue_ms: f64,
    /// Prefill latency (ms).
    pub prefill_ms: f64,
    /// Total decode time (ms) across all generated tokens.
    pub decode_ms: f64,
    /// Wall-clock submit-to-first-token latency (ms); 0 when no token
    /// was produced (rejection / pre-prefill cancel).
    pub ttft_ms: f64,
    /// Error detail when rejected.
    pub error: Option<String>,
}

/// One item of a request's incremental event stream.
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// The request left the queue and entered prefill.
    Started { id: u64, queue_ms: f64 },
    /// One generated token. `index` is its position in the output
    /// (0-based); `decode_ms` is this token's share of its batched
    /// decode step (0 for the first token, which prefill produces).
    Token { id: u64, token: i32, index: usize, decode_ms: f64 },
    /// Terminal: the request finished, failed, or was cancelled.
    Finished(Response),
}

impl EngineEvent {
    pub fn id(&self) -> u64 {
        match self {
            EngineEvent::Started { id, .. } | EngineEvent::Token { id, .. } => *id,
            EngineEvent::Finished(r) => r.id,
        }
    }

    /// Rewrite the request id (the server maps internal ids back to the
    /// client-supplied ones).
    pub fn set_id(&mut self, new_id: u64) {
        match self {
            EngineEvent::Started { id, .. } | EngineEvent::Token { id, .. } => *id = new_id,
            EngineEvent::Finished(r) => r.id = new_id,
        }
    }

    pub fn as_finished(&self) -> Option<&Response> {
        match self {
            EngineEvent::Finished(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_finished(self) -> Option<Response> {
        match self {
            EngineEvent::Finished(r) => Some(r),
            _ => None,
        }
    }
}

/// Engine-internal per-request tracking.
#[derive(Debug)]
pub(crate) enum SeqPhase {
    Queued,
    /// Chunked prefill in flight: `done_tokens` prompt tokens processed
    /// so far (including any prefix-cache hit that skipped real work).
    Prefilling { done_tokens: usize },
    Decoding,
}

#[derive(Debug)]
pub(crate) struct Tracked {
    pub req: Request,
    pub phase: SeqPhase,
    pub output: Vec<i32>,
    pub enqueued: Instant,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub queue_ms: f64,
    pub ttft_ms: f64,
    /// Next token to feed at the coming decode step.
    pub next_token: i32,
    /// Per-request seeded sampler (owns the request's RNG stream).
    pub sampler: Sampler,
}

impl Tracked {
    pub fn new(req: Request) -> Tracked {
        let sampler = Sampler::new(&req.sampling);
        Tracked {
            req,
            phase: SeqPhase::Queued,
            output: Vec::new(),
            enqueued: Instant::now(),
            prefill_ms: 0.0,
            decode_ms: 0.0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
            next_token: 0,
            sampler,
        }
    }

    /// Record one generated token and return its stream event. The
    /// first token stamps the request's wall-clock TTFT.
    pub fn push_token(&mut self, tok: i32, decode_ms: f64) -> EngineEvent {
        if self.output.is_empty() {
            self.ttft_ms = self.enqueued.elapsed().as_secs_f64() * 1e3;
        }
        self.output.push(tok);
        self.next_token = tok;
        EngineEvent::Token {
            id: self.req.id,
            token: tok,
            index: self.output.len() - 1,
            decode_ms,
        }
    }

    pub fn respond(&self, finish: FinishReason) -> Response {
        Response {
            id: self.req.id,
            output: self.output.clone(),
            finish,
            queue_ms: self.queue_ms,
            prefill_ms: self.prefill_ms,
            decode_ms: self.decode_ms,
            ttft_ms: self.ttft_ms,
            error: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Eos.as_str(), "eos");
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
    }

    #[test]
    fn sampling_defaults_are_greedy() {
        let p = SamplingParams::default();
        assert_eq!(p.temperature, 0.0);
        assert_eq!(p.top_k, 0);
        assert_eq!(p.top_p, 1.0);
        assert!(p.stop.is_empty());
        assert!(!p.ignore_eos);
    }

    #[test]
    fn tracked_responds_with_metrics() {
        let mut t = Tracked::new(Request {
            id: 7,
            tokens: vec![1],
            max_new_tokens: 4,
            dma: true,
            ..Default::default()
        });
        t.prefill_ms = 1.5;
        t.decode_ms = 3.0;
        t.queue_ms = 0.5;
        let ev = t.push_token(9, 0.0);
        assert!(matches!(ev, EngineEvent::Token { id: 7, token: 9, index: 0, .. }));
        assert!(t.ttft_ms >= 0.0);
        let ev = t.push_token(8, 0.25);
        assert!(matches!(ev, EngineEvent::Token { index: 1, .. }));
        let r = t.respond(FinishReason::Length);
        assert_eq!(r.id, 7);
        assert_eq!(r.output, vec![9, 8]);
        assert_eq!(r.finish, FinishReason::Length);
        assert!(r.prefill_ms > 0.0);
    }

    #[test]
    fn event_id_rewrite() {
        let mut ev = EngineEvent::Token { id: 3, token: 1, index: 0, decode_ms: 0.0 };
        assert_eq!(ev.id(), 3);
        ev.set_id(99);
        assert_eq!(ev.id(), 99);
        let mut t = Tracked::new(Request { id: 4, tokens: vec![1], ..Default::default() });
        t.push_token(2, 0.0);
        let mut fin = EngineEvent::Finished(t.respond(FinishReason::Eos));
        fin.set_id(42);
        assert_eq!(fin.id(), 42);
        assert_eq!(fin.as_finished().unwrap().id, 42);
        assert_eq!(fin.into_finished().unwrap().output, vec![2]);
    }
}
