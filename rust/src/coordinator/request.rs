//! Request/response types, sampling parameters, the incremental
//! [`EngineEvent`] stream, and the request state machine.
//!
//! The serving contract is event-based and *group*-shaped: one request
//! asks for `n` parallel samples (optionally reranked from `best_of`
//! generated candidates), the engine emits `Started` when the group is
//! admitted, one `Token` per generated token tagged with its candidate
//! index, and a terminal `Finished` carrying the assembled [`Response`]
//! with the `n` finalists ranked by cumulative logprob — so clients can
//! stream per-candidate token lines and measure TTFT, while batch
//! callers keep consuming the back-compat `Response` built from the
//! same events (for `n = 1` its shape is exactly the PR-3 contract).

use super::sampling::Sampler;
use std::time::Instant;

/// Per-request decoding controls. `temperature == 0` (the default)
/// selects greedy argmax; otherwise sampling is fully deterministic
/// given `seed` — each candidate of the group owns its own RNG stream
/// with a seed derived from `(seed, candidate)`
/// ([`super::sampling::derive_seed`]), so batch composition, scheduling,
/// thread counts, and sibling candidates cannot change a candidate's
/// tokens.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; 0 means greedy (argmax).
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling (0 = all).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability mass >= `top_p`
    /// (1.0 = off).
    pub top_p: f32,
    /// Seed of the request's private RNG stream (candidate 0 uses it
    /// verbatim, so candidate 0 of a group replays an `n = 1` request).
    pub seed: u64,
    /// Generation stops when any of these token ids is produced
    /// (the stop token is included in the output, like EOS).
    pub stop: Vec<i32>,
    /// Keep generating past the EOS token (benchmarks, fixed-length
    /// probes).
    pub ignore_eos: bool,
    /// Parallel samples to return (candidates share one prompt prefill
    /// and fork the quantized KV copy-on-write at the decode boundary).
    /// 0 is treated as 1.
    pub n: usize,
    /// Candidates to *generate* before keeping the best `n` by
    /// cumulative logprob (0 = same as `n`; must be >= `n` otherwise).
    pub best_of: usize,
    /// Report per-token logprobs in `Token` events and the terminal
    /// candidates (the wire shape only grows when this is set).
    pub logprobs: bool,
    /// Per-request wall-clock budget in milliseconds measured from
    /// submission; 0 means no per-request deadline. Enforced at the
    /// engine step boundary (finish reason `timeout`), combined with
    /// the server-wide `--request-timeout-ms` / `--queue-timeout-ms`
    /// knobs — whichever bound is tighter wins.
    pub deadline_ms: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop: Vec::new(),
            ignore_eos: false,
            n: 1,
            best_of: 0,
            logprobs: false,
            deadline_ms: 0,
        }
    }
}

impl SamplingParams {
    /// Candidates the engine actually runs: `max(best_of, n, 1)`.
    pub fn group_size(&self) -> usize {
        self.best_of.max(self.n).max(1)
    }

    /// Finalists the terminal response reports: `n` clamped to the
    /// group size.
    pub fn num_return(&self) -> usize {
        self.n.max(1).min(self.group_size())
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    /// Use the DMA (mixed-precision) prefill path.
    pub dma: bool,
    pub sampling: SamplingParams,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            id: 0,
            tokens: Vec::new(),
            max_new_tokens: 16,
            dma: true,
            sampling: SamplingParams::default(),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated the EOS token.
    Eos,
    /// Generated one of the request's stop tokens.
    Stop,
    /// Hit the per-request new-token limit.
    Length,
    /// Hit the engine cache capacity.
    CacheFull,
    /// Rejected at admission (queue full / prompt too long / bad group).
    Rejected,
    /// Cancelled by the client (or its connection going away).
    Cancelled,
    /// Exceeded its deadline (`deadline_ms`, `--request-timeout-ms`,
    /// or `--queue-timeout-ms`) and was cancelled by the engine.
    Timeout,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Rejected => "rejected",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Timeout => "timeout",
        }
    }
}

/// One finalist of a sequence group, as reported by the terminal
/// [`Response`]. `candidate` is the stable in-group index (the one the
/// stream's `Token` events were tagged with), preserved through the
/// logprob-ranked reordering.
#[derive(Clone, Debug)]
pub struct CandidateResult {
    pub candidate: usize,
    pub output: Vec<i32>,
    pub finish: FinishReason,
    /// Sum of the per-token logprobs under the raw model distribution
    /// (the `best_of` ranking key). 0 for requests that neither set
    /// `logprobs` nor run multiple candidates — the engine skips the
    /// per-token log-sum-exp entirely there.
    pub cum_logprob: f64,
    /// Per-token logprob of each output token (zeros when untracked;
    /// the wire only carries it when the request set `logprobs`).
    pub logprobs: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Best finalist's output (identical to `candidates[0].output` when
    /// finalists exist) — the back-compat `n = 1` view.
    pub output: Vec<i32>,
    pub finish: FinishReason,
    /// The group's finalists, best first (cum logprob descending,
    /// candidate index breaking ties; cancelled candidates sort last).
    /// One entry for a plain `n = 1` request; empty on rejection.
    pub candidates: Vec<CandidateResult>,
    /// Wall-clock milliseconds spent queued before prefill.
    pub queue_ms: f64,
    /// Prefill latency (ms) — shared by the whole group.
    pub prefill_ms: f64,
    /// Total decode time (ms) across all candidates' generated tokens.
    pub decode_ms: f64,
    /// Wall-clock submit-to-first-token latency (ms); 0 when no token
    /// was produced (rejection / pre-prefill cancel).
    pub ttft_ms: f64,
    /// Error detail when rejected.
    pub error: Option<String>,
    /// When the engine shed this request under KV pressure
    /// (`--shed-policy`): suggested client backoff, computed from the
    /// rolling decode-throughput window. `None` everywhere else.
    pub retry_after_ms: Option<u64>,
}

/// One item of a request's incremental event stream.
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// The request left the queue and entered prefill.
    Started { id: u64, queue_ms: f64 },
    /// One generated token. `candidate` is the producing candidate's
    /// in-group index (0 for plain requests); `index` is the token's
    /// position in that candidate's output (0-based); `logprob` is its
    /// log-probability under the raw model distribution — tracked only
    /// when the request set `logprobs` or runs more than one candidate
    /// (`best_of` ranking needs it); 0 otherwise, sparing the default
    /// greedy hot path an O(vocab) log-sum-exp per token. `decode_ms` is
    /// this token's share of its batched decode step (0 for a first
    /// token, which prefill produces).
    Token {
        id: u64,
        candidate: usize,
        token: i32,
        index: usize,
        logprob: f32,
        decode_ms: f64,
    },
    /// The group's worker died and a supervisor replayed the request on
    /// a fresh engine. The seeded sampler regenerates the first
    /// `replayed_tokens` tokens of each candidate bit-exactly, so the
    /// router suppresses them and the client's stream continues with
    /// consistent indices; this event tells streaming clients a restart
    /// happened (and batch clients nothing changed).
    Restarted { id: u64, replayed_tokens: usize },
    /// Terminal: the request finished, failed, or was cancelled.
    Finished(Response),
}

impl EngineEvent {
    pub fn id(&self) -> u64 {
        match self {
            EngineEvent::Started { id, .. }
            | EngineEvent::Token { id, .. }
            | EngineEvent::Restarted { id, .. } => *id,
            EngineEvent::Finished(r) => r.id,
        }
    }

    /// Rewrite the request id (the server maps internal ids back to the
    /// client-supplied ones).
    pub fn set_id(&mut self, new_id: u64) {
        match self {
            EngineEvent::Started { id, .. }
            | EngineEvent::Token { id, .. }
            | EngineEvent::Restarted { id, .. } => *id = new_id,
            EngineEvent::Finished(r) => r.id = new_id,
        }
    }

    pub fn as_finished(&self) -> Option<&Response> {
        match self {
            EngineEvent::Finished(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_finished(self) -> Option<Response> {
        match self {
            EngineEvent::Finished(r) => Some(r),
            _ => None,
        }
    }
}

/// Engine-internal per-request tracking.
#[derive(Debug)]
pub(crate) enum SeqPhase {
    Queued,
    /// Chunked prefill in flight: `done_tokens` prompt tokens processed
    /// so far (including any prefix-cache hit that skipped real work).
    Prefilling { done_tokens: usize },
    Decoding,
}

/// Group-level bookkeeping of one tracked request: lifecycle phase and
/// timing. Per-candidate state (sampler, output, KV payload, pool
/// holdings) lives in the engine's candidate records — the group shares
/// one queue slot, one prefill, and one terminal response.
#[derive(Debug)]
pub(crate) struct Tracked {
    pub req: Request,
    pub phase: SeqPhase,
    pub enqueued: Instant,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub queue_ms: f64,
    pub ttft_ms: f64,
    /// Candidate indices cancelled before the decode boundary existed
    /// (the engine skips forking them instead of cancelling a fork).
    pub pre_cancelled: Vec<usize>,
}

impl Tracked {
    pub fn new(req: Request) -> Tracked {
        Tracked {
            req,
            phase: SeqPhase::Queued,
            enqueued: Instant::now(),
            prefill_ms: 0.0,
            decode_ms: 0.0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
            pre_cancelled: Vec::new(),
        }
    }

    /// Per-candidate sampler (derived seed; candidate 0 replays `n = 1`).
    pub fn sampler_for(&self, candidate: usize) -> Sampler {
        Sampler::for_candidate(&self.req.sampling, candidate)
    }

    /// Stamp the group's wall-clock TTFT at its first generated token
    /// (idempotent: only the first call records).
    pub fn stamp_first_token(&mut self) {
        if self.ttft_ms == 0.0 {
            self.ttft_ms = self.enqueued.elapsed().as_secs_f64() * 1e3;
        }
    }

    /// Assemble the terminal response from ranked finalists (best
    /// first). `fallback` is the group-level finish when no candidate
    /// exists (rejection, pre-prefill cancel).
    pub fn respond(
        &self,
        fallback: FinishReason,
        finalists: Vec<CandidateResult>,
    ) -> Response {
        let output = finalists.first().map(|c| c.output.clone()).unwrap_or_default();
        let finish = finalists.first().map(|c| c.finish).unwrap_or(fallback);
        Response {
            id: self.req.id,
            output,
            finish,
            candidates: finalists,
            queue_ms: self.queue_ms,
            prefill_ms: self.prefill_ms,
            decode_ms: self.decode_ms,
            ttft_ms: self.ttft_ms,
            error: None,
            retry_after_ms: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Eos.as_str(), "eos");
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
        assert_eq!(FinishReason::Timeout.as_str(), "timeout");
    }

    #[test]
    fn sampling_defaults_are_greedy_single() {
        let p = SamplingParams::default();
        assert_eq!(p.temperature, 0.0);
        assert_eq!(p.top_k, 0);
        assert_eq!(p.top_p, 1.0);
        assert!(p.stop.is_empty());
        assert!(!p.ignore_eos);
        assert_eq!(p.n, 1);
        assert_eq!(p.best_of, 0);
        assert!(!p.logprobs);
        assert_eq!(p.group_size(), 1);
        assert_eq!(p.num_return(), 1);
    }

    #[test]
    fn group_size_combines_n_and_best_of() {
        let p = SamplingParams { n: 2, best_of: 4, ..Default::default() };
        assert_eq!(p.group_size(), 4);
        assert_eq!(p.num_return(), 2);
        // best_of 0 means "= n"; n 0 is treated as 1.
        let p = SamplingParams { n: 3, ..Default::default() };
        assert_eq!(p.group_size(), 3);
        assert_eq!(p.num_return(), 3);
        let p = SamplingParams { n: 0, best_of: 2, ..Default::default() };
        assert_eq!(p.group_size(), 2);
        assert_eq!(p.num_return(), 1);
    }

    #[test]
    fn tracked_responds_with_metrics() {
        let mut t = Tracked::new(Request {
            id: 7,
            tokens: vec![1],
            max_new_tokens: 4,
            dma: true,
            ..Default::default()
        });
        t.prefill_ms = 1.5;
        t.decode_ms = 3.0;
        t.queue_ms = 0.5;
        t.stamp_first_token();
        let first = t.ttft_ms;
        assert!(first > 0.0);
        t.stamp_first_token();
        assert_eq!(t.ttft_ms, first, "TTFT stamps once");
        let finalists = vec![CandidateResult {
            candidate: 0,
            output: vec![9, 8],
            finish: FinishReason::Length,
            cum_logprob: -1.25,
            logprobs: vec![-0.5, -0.75],
        }];
        let r = t.respond(FinishReason::Cancelled, finalists);
        assert_eq!(r.id, 7);
        assert_eq!(r.output, vec![9, 8]);
        assert_eq!(r.finish, FinishReason::Length, "best finalist wins");
        assert_eq!(r.candidates.len(), 1);
        assert!((r.candidates[0].cum_logprob + 1.25).abs() < 1e-12);
        assert!(r.prefill_ms > 0.0);
        // No finalists: the fallback reason and an empty output.
        let r = t.respond(FinishReason::Rejected, vec![]);
        assert!(r.output.is_empty());
        assert_eq!(r.finish, FinishReason::Rejected);
    }

    #[test]
    fn event_id_rewrite() {
        let mut ev = EngineEvent::Token {
            id: 3,
            candidate: 1,
            token: 1,
            index: 0,
            logprob: -0.1,
            decode_ms: 0.0,
        };
        assert_eq!(ev.id(), 3);
        ev.set_id(99);
        assert_eq!(ev.id(), 99);
        let t = Tracked::new(Request { id: 4, tokens: vec![1], ..Default::default() });
        let mut fin = EngineEvent::Finished(t.respond(
            FinishReason::Eos,
            vec![CandidateResult {
                candidate: 0,
                output: vec![2],
                finish: FinishReason::Eos,
                cum_logprob: -0.5,
                logprobs: vec![-0.5],
            }],
        ));
        fin.set_id(42);
        assert_eq!(fin.id(), 42);
        assert_eq!(fin.as_finished().unwrap().id, 42);
        assert_eq!(fin.into_finished().unwrap().output, vec![2]);
    }
}
