//! The serving engine: continuous batching over a [`ModelBackend`].
//!
//! Policy (vLLM-style, chunked-prefill interleaved):
//!
//! 1. While batch slots and KV blocks are free, admit a queued request:
//!    consult the radix prefix cache ([`super::radix`]) for shared
//!    quantized pages, pin them (pool fork), and open a streaming
//!    prefill ([`ModelBackend::begin_prefill`]).
//! 2. Advance every prefilling sequence by one `--prefill-chunk` slice —
//!    prompts enter the cache incrementally, so a long prompt never
//!    stalls decoding sequences for its full length.
//! 3. Run up to `decode_slice` batched decode steps over the decoding
//!    slots, then loop back to (1)/(2).
//! 4. A sequence retires on EOS, a stop token, its token budget, cache
//!    capacity, or a [`Engine::cancel`]; when a quantized prefill
//!    completes, its full prompt pages are donated to the radix cache
//!    (block accounting forked out of the sequence's table) so later
//!    requests sharing the prefix skip that prefill work entirely.
//!
//! Output is an incremental [`EngineEvent`] stream: `Started` on
//! admission, one `Token` per generated token (sampled through the
//! request's seeded [`super::sampling::Sampler`]), and a terminal
//! `Finished` carrying the assembled back-compat [`Response`].
//!
//! Admission uses the paged [`BlockPool`] accounting: a request is only
//! admitted when its *unshared* prompt + token budget fit in free KV
//! blocks (cold cached pages are LRU-evicted under pressure), so decode
//! can never deadlock on cache space. Cancellation releases the
//! sequence's own allocation plus its radix forks and re-checks the
//! pool's byte accounting against a from-scratch recount.

use super::radix::{PrefixHit, RadixCache};
use super::request::{EngineEvent, FinishReason, Request, Response, SeqPhase, Tracked};
use crate::config::EngineConfig;
use crate::kvcache::{BlockPool, SeqId, SeqKv};
use crate::kvquant::{KvFormat, KvPolicy, KvQuantConfig, QuantSlotKv, PAGE_TOKENS};
use crate::runtime::{ModelBackend, PrefillSeq};
use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

/// Scheduler state of one batch slot.
enum SlotState {
    /// Streaming prefill in flight (advanced one chunk per step).
    Prefilling(PrefillSeq),
    /// Generating tokens over its cache.
    Decoding(SeqKv),
}

struct Active {
    tracked: Tracked,
    state: SlotState,
    /// Engine-issued [`BlockPool`] id of this sequence's own allocation.
    /// Client-chosen request ids never enter the pool namespace — every
    /// pool id (sequences, radix nodes, shared forks) comes from one
    /// internal counter, so they cannot collide.
    pool_id: SeqId,
    /// Pool ids forked from radix-cache nodes (pins the shared pages'
    /// admission blocks for this sequence's lifetime).
    shared_forks: Vec<SeqId>,
    /// Prompt tokens imported from the prefix cache (never prefilled
    /// here).
    shared_tokens: usize,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub completed: u64,
    pub rejected: u64,
    /// Requests cancelled mid-flight (queued, prefilling, or decoding).
    pub cancelled: u64,
    /// Prompt tokens actually run through the model (prefix-cache hits
    /// are excluded — they skip prefill).
    pub prefill_tokens: u64,
    /// Prefill chunks processed (chunked scheduler work units).
    pub prefill_chunks: u64,
    /// Scheduler iterations ([`Engine::step`] calls).
    pub engine_steps: u64,
    /// Requests that imported at least one shared page.
    pub prefix_hits: u64,
    /// Prompt tokens served from the radix prefix cache instead of
    /// prefill.
    pub prefix_hit_tokens: u64,
    pub decode_tokens: u64,
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    /// Admission accounting cost of one cached token in bytes at the
    /// configured `kv_format` (all layers/heads, K + V).
    pub kv_bytes_per_token: u64,
    /// The same cost at f32 — `kv_bytes_per_token / kv_f32_bytes_per_token`
    /// is the cache compression the format buys.
    pub kv_f32_bytes_per_token: u64,
    /// Peak resident bytes of all active sequence caches.
    pub kv_bytes_peak: u64,
    /// Per-precision page-decode hits (quantized caches only).
    pub kv_pages: crate::metrics::KvPageStats,
}

impl EngineStats {
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_batch_sum as f64 / self.decode_steps as f64
        }
    }

    /// Mean prefill chunks per scheduler step — the interleaving ratio
    /// the chunked scheduler actually achieved.
    pub fn mean_chunks_per_step(&self) -> f64 {
        if self.engine_steps == 0 {
            0.0
        } else {
            self.prefill_chunks as f64 / self.engine_steps as f64
        }
    }

    /// Cache bytes-per-token compression vs f32 (1.0 for the f32 cache).
    pub fn kv_compression(&self) -> f64 {
        crate::metrics::compression_ratio(
            self.kv_f32_bytes_per_token as usize,
            self.kv_bytes_per_token as usize,
        )
    }
}

pub struct Engine {
    pub cfg: EngineConfig,
    backend: Box<dyn ModelBackend>,
    queue: VecDeque<Tracked>,
    active: Vec<Option<Active>>,
    pool: BlockPool,
    eos_token: i32,
    /// Quantized-cache layout, `None` for the f32 cache.
    kv_quant: Option<KvQuantConfig>,
    /// `(n_layers, n_kv_heads, d_head)` from the backend.
    kv_dims: (usize, usize, usize),
    /// Radix prefix cache of shared quantized pages (quantized formats
    /// with `prefix_cache` on).
    radix: Option<RadixCache>,
    /// Effective prefill chunk (config value rounded up to whole pages).
    prefill_chunk: usize,
    /// Id source for every [`BlockPool`] sequence this engine creates
    /// (request allocations, radix nodes, shared forks). Pool ids are
    /// never taken from client-supplied request ids.
    next_internal: u64,
    pub stats: EngineStats,
}

impl Engine {
    pub fn new(mut backend: Box<dyn ModelBackend>, cfg: EngineConfig, eos_token: i32) -> Engine {
        // Perf knobs: intra-step worker threads and the decoded-page
        // cache budget (ignored by backends without those mechanisms).
        backend.set_perf(cfg.threads, cfg.decoded_cache_bytes);
        let max_slots = backend.decode_buckets().into_iter().max().unwrap_or(1);
        // Format-aware KV accounting: the physical budget is what the f32
        // slots would occupy (max_slots full-length caches); cheaper
        // formats get proportionally more 16-token admission blocks.
        let block_tokens = PAGE_TOKENS;
        let (nl, hk, dh) = backend.kv_dims();
        let f32_bpt = 2 * nl * hk * dh * 4;
        let bpt = 2 * nl * hk * cfg.kv_format.row_bytes(dh);
        let budget = max_slots * backend.cache_len() * f32_bpt;
        let kv_quant = match cfg.kv_format {
            KvFormat::F32 => None,
            format => Some(KvQuantConfig {
                format,
                page_tokens: block_tokens,
                policies: if cfg.kv_precision_policies.is_empty() {
                    vec![KvPolicy::default()]
                } else {
                    cfg.kv_precision_policies.clone()
                },
            }),
        };
        // Sharing and chunking align on page boundaries.
        let prefill_chunk = cfg.prefill_chunk.max(1).next_multiple_of(block_tokens);
        let radix = if cfg.prefix_cache && kv_quant.is_some() {
            Some(RadixCache::new(block_tokens))
        } else {
            None
        };
        let stats = EngineStats {
            kv_bytes_per_token: bpt as u64,
            kv_f32_bytes_per_token: f32_bpt as u64,
            ..Default::default()
        };
        Engine {
            cfg,
            pool: BlockPool::with_byte_budget(budget, block_tokens, bpt),
            active: (0..max_slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            backend,
            eos_token,
            kv_quant,
            kv_dims: (nl, hk, dh),
            radix,
            prefill_chunk,
            next_internal: 0,
            stats,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Pages currently resident in the radix prefix cache.
    pub fn prefix_cache_pages(&self) -> usize {
        self.radix.as_ref().map_or(0, RadixCache::len)
    }

    /// Number of requests currently queued + active (router load signal).
    pub fn load(&self) -> usize {
        self.queue.len() + self.active.iter().flatten().count()
    }

    /// Bytes of KV blocks currently referenced in the admission pool
    /// (running sequences + retained radix pages). Recounted from the
    /// refcount plane on every call — cancellation tests compare this
    /// against the pre-admission value.
    pub fn kv_bytes_in_use(&self) -> usize {
        self.pool.bytes_in_use()
    }

    /// Free admission blocks in the KV pool.
    pub fn kv_free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Structural pool-accounting check (used by cancellation paths and
    /// tests).
    pub fn pool_check(&self) -> crate::Result<()> {
        self.pool.check_invariants()
    }

    /// Submit a request; returns an immediate rejection response when
    /// admission is impossible (prompt too long / queue full).
    pub fn submit(&mut self, req: Request) -> Option<Response> {
        if self.queue.len() >= self.cfg.queue_limit {
            self.stats.rejected += 1;
            return Some(Response {
                id: req.id,
                output: vec![],
                finish: FinishReason::Rejected,
                queue_ms: 0.0,
                prefill_ms: 0.0,
                decode_ms: 0.0,
                ttft_ms: 0.0,
                error: Some("queue full".into()),
            });
        }
        let budget = req.tokens.len() + req.max_new_tokens.min(self.cfg.max_new_tokens);
        if req.tokens.is_empty() || budget > self.backend.cache_len() {
            self.stats.rejected += 1;
            return Some(Response {
                id: req.id,
                output: vec![],
                finish: FinishReason::Rejected,
                queue_ms: 0.0,
                prefill_ms: 0.0,
                decode_ms: 0.0,
                ttft_ms: 0.0,
                error: Some(format!(
                    "prompt+budget {budget} exceeds cache {}",
                    self.backend.cache_len()
                )),
            });
        }
        self.queue.push_back(Tracked::new(req));
        None
    }

    /// Cancel a request by id, wherever it is in its lifecycle. Queued
    /// requests are dropped before admission; active ones release their
    /// KV holdings — the sequence's own pool allocation plus the forks
    /// pinning radix pages, and the in-flight cache payload (dropping a
    /// quantized store decrements the shared pages' `Arc` counts, which
    /// is what frees a COW frontier mid-prefill). Returns the terminal
    /// event, or `None` when the id is not in flight (already finished).
    pub fn cancel(&mut self, id: u64) -> crate::Result<Option<EngineEvent>> {
        if let Some(pos) = self.queue.iter().position(|t| t.req.id == id) {
            let mut t = self.queue.remove(pos).unwrap();
            t.queue_ms = t.enqueued.elapsed().as_secs_f64() * 1e3;
            self.stats.cancelled += 1;
            return Ok(Some(EngineEvent::Finished(t.respond(FinishReason::Cancelled))));
        }
        let Some(idx) = self
            .active
            .iter()
            .position(|a| a.as_ref().is_some_and(|a| a.tracked.req.id == id))
        else {
            return Ok(None);
        };
        let Active { tracked, state, pool_id, shared_forks, .. } =
            self.active[idx].take().unwrap();
        // Drop the cache payload before releasing the accounting: a
        // mid-prefill quantized store holds Arc'd shared pages whose
        // admission blocks the forks below pin.
        drop(state);
        self.release_holdings(pool_id, &shared_forks)?;
        // Recount path: the byte accounting must match a from-scratch
        // recount of the refcount plane after the release.
        self.pool.check_invariants()?;
        self.stats.cancelled += 1;
        Ok(Some(EngineEvent::Finished(tracked.respond(FinishReason::Cancelled))))
    }

    fn free_slot(&self) -> Option<usize> {
        self.active.iter().position(Option::is_none)
    }

    fn next_internal_id(&mut self) -> u64 {
        let id = self.next_internal;
        self.next_internal += 1;
        id
    }

    /// Release every pool holding of a sequence: its own allocation plus
    /// the radix-node forks pinning shared pages.
    fn release_holdings(&mut self, pool_id: SeqId, shared_forks: &[SeqId]) -> crate::Result<()> {
        self.pool.release(pool_id)?;
        for &id in shared_forks {
            self.pool.release(id)?;
        }
        Ok(())
    }

    /// The finish reason `tok` implies for `t`, if any (EOS respects
    /// `ignore_eos`, then the request's stop set, then the length cap).
    fn finish_after_token(&self, t: &Tracked, tok: i32) -> Option<FinishReason> {
        let max_new = t.req.max_new_tokens.min(self.cfg.max_new_tokens);
        if tok == self.eos_token && !t.req.sampling.ignore_eos {
            Some(FinishReason::Eos)
        } else if t.req.sampling.stop.contains(&tok) {
            Some(FinishReason::Stop)
        } else if t.output.len() >= max_new {
            Some(FinishReason::Length)
        } else {
            None
        }
    }

    /// Try to admit one queued request into a free slot (phase 1).
    /// Returns whether admission made progress (keep calling) and pushes
    /// `Started` / terminal events.
    fn try_admit(&mut self, out: &mut Vec<EngineEvent>) -> crate::Result<bool> {
        let Some(slot_idx) = self.free_slot() else {
            return Ok(false);
        };
        let Some(head) = self.queue.front() else {
            return Ok(false);
        };
        let budget =
            head.req.tokens.len() + head.req.max_new_tokens.min(self.cfg.max_new_tokens);

        // Prefix-cache lookup. Sharing is capped at a prefill-chunk
        // boundary strictly inside the prompt: the warm run's remaining
        // chunk boundaries then coincide with the cold run's, so the
        // suffix pages — and every decoded token — reproduce exactly, and
        // at least one chunk always runs to produce the last-position
        // logits.
        let max_share =
            (head.req.tokens.len().saturating_sub(1) / self.prefill_chunk) * self.prefill_chunk;
        let mut hit = match &mut self.radix {
            Some(r) if max_share > 0 => r.lookup(&head.req.tokens, head.req.dma, max_share),
            _ => PrefixHit::empty(),
        };
        // A hit may end mid-chunk (tail pages evicted); keep only whole
        // chunks so the suffix prefill chunks exactly like a cold run.
        hit.align_to(self.prefill_chunk, PAGE_TOKENS);
        // Pin the shared nodes before any eviction can release them.
        let mut shared_forks = Vec::with_capacity(hit.pool_ids.len());
        for &node_id in &hit.pool_ids {
            let child = self.next_internal_id();
            self.pool.fork(node_id, child)?;
            shared_forks.push(child);
        }

        // Admission: the unshared prompt + token budget must fit; cold
        // cached pages are evicted LRU-first to make room. Stop as soon
        // as an eviction frees no block (the page is still pinned by a
        // running sequence's fork) — flushing more of the cache could not
        // help this admission either.
        let own_budget = budget - hit.tokens;
        while !self.pool.can_admit(own_budget) {
            // Only unpinned pages qualify (no running sequence forks
            // their block), so every eviction frees a block.
            let pool = &self.pool;
            let evicted = self.radix.as_mut().and_then(|r| {
                r.evict_lru_leaf(|id| pool.seq_max_refcount(id) == Some(1))
            });
            match evicted {
                Some(id) => self.pool.release(id)?,
                None => break,
            }
        }
        if !self.pool.can_admit(own_budget) {
            for id in shared_forks {
                self.pool.release(id)?;
            }
            return Ok(false);
        }

        let mut tracked = self.queue.pop_front().unwrap();
        tracked.queue_ms = tracked.enqueued.elapsed().as_secs_f64() * 1e3;
        let pool_id = self.next_internal_id();
        self.pool.allocate(pool_id, own_budget)?;

        // Seed a quantized slot with the shared pages (zero-copy) and
        // open the streaming prefill.
        let seed = if hit.tokens > 0 {
            let (nl, hk, dh) = self.kv_dims;
            let mut slot =
                QuantSlotKv::new(self.kv_quant.clone().unwrap(), nl, hk, dh);
            hit.seed(&mut slot);
            Some(slot)
        } else {
            None
        };
        let seq = match self.backend.begin_prefill(
            &tracked.req.tokens,
            tracked.req.dma,
            self.kv_quant.as_ref(),
            seed,
        ) {
            Ok(s) => s,
            Err(e) => {
                self.release_holdings(pool_id, &shared_forks)?;
                self.stats.rejected += 1;
                let mut resp = tracked.respond(FinishReason::Rejected);
                resp.error = Some(e.to_string());
                out.push(EngineEvent::Finished(resp));
                return Ok(true);
            }
        };
        if hit.tokens > 0 {
            self.stats.prefix_hits += 1;
            self.stats.prefix_hit_tokens += hit.tokens as u64;
        }
        out.push(EngineEvent::Started {
            id: tracked.req.id,
            queue_ms: tracked.queue_ms,
        });
        tracked.phase = SeqPhase::Prefilling { done_tokens: seq.done };
        self.active[slot_idx] = Some(Active {
            tracked,
            state: SlotState::Prefilling(seq),
            pool_id,
            shared_forks,
            shared_tokens: hit.tokens,
        });
        Ok(true)
    }

    /// Advance the prefilling sequence in `idx` by one chunk (phase 2);
    /// pushes the sequence's events when it finishes (or fails) outright.
    fn advance_prefill(&mut self, idx: usize, out: &mut Vec<EngineEvent>) -> crate::Result<()> {
        let is_prefilling = matches!(
            self.active[idx].as_ref().map(|a| &a.state),
            Some(SlotState::Prefilling(_))
        );
        if !is_prefilling {
            return Ok(());
        }
        let mut act = self.active[idx].take().unwrap();
        let SlotState::Prefilling(ref mut seq) = act.state else { unreachable!() };
        let before = seq.done;
        let t0 = Instant::now();
        if let Err(e) = self.backend.prefill_chunk(seq, self.prefill_chunk) {
            self.release_holdings(act.pool_id, &act.shared_forks)?;
            self.stats.rejected += 1;
            let mut resp = act.tracked.respond(FinishReason::Rejected);
            resp.error = Some(e.to_string());
            out.push(EngineEvent::Finished(resp));
            return Ok(());
        }
        act.tracked.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.stats.prefill_chunks += 1;
        let SlotState::Prefilling(ref seq) = act.state else { unreachable!() };
        self.stats.prefill_tokens += (seq.done - before) as u64;
        act.tracked.phase = SeqPhase::Prefilling { done_tokens: seq.done };
        if !seq.is_done() {
            self.active[idx] = Some(act);
            return Ok(());
        }
        self.complete_prefill(idx, act, out)
    }

    /// Prefill finished: close the streaming state, donate prompt pages
    /// to the radix cache, sample the first token and either retire the
    /// sequence immediately or move it to decoding.
    fn complete_prefill(
        &mut self,
        idx: usize,
        act: Active,
        out: &mut Vec<EngineEvent>,
    ) -> crate::Result<()> {
        let Active { mut tracked, state, pool_id, shared_forks, shared_tokens } = act;
        let SlotState::Prefilling(seq) = state else { unreachable!() };
        // finish_prefill is real work for deferring backends (PJRT runs
        // the whole monolithic prefill here) — it counts as prefill time.
        let t0 = Instant::now();
        let pre = match self.backend.finish_prefill(seq) {
            Ok(o) => o,
            Err(e) => {
                self.release_holdings(pool_id, &shared_forks)?;
                self.stats.rejected += 1;
                let mut resp = tracked.respond(FinishReason::Rejected);
                resp.error = Some(e.to_string());
                out.push(EngineEvent::Finished(resp));
                return Ok(());
            }
        };
        tracked.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;

        // Donate the prompt's full pages to the prefix cache: each new
        // page's admission block is forked out of this sequence's table,
        // so it stays reserved after the sequence releases.
        if let (Some(radix), SeqKv::Quant(q)) = (self.radix.as_mut(), &pre.kv) {
            let shared_pages = shared_tokens / PAGE_TOKENS;
            let pool = &mut self.pool;
            let next_internal = &mut self.next_internal;
            radix.insert(&tracked.req.tokens, tracked.req.dma, q, |j| {
                if j < shared_pages {
                    // An upstream page was evicted mid-flight; this
                    // sequence's blocks only cover its own suffix.
                    return None;
                }
                let id = *next_internal;
                match pool.fork_block(pool_id, id, j - shared_pages) {
                    Ok(()) => {
                        *next_internal += 1;
                        Some(id)
                    }
                    Err(_) => None,
                }
            });
        }

        // First generated token comes from the prefill logits.
        let tok = tracked.sampler.sample(&pre.last_logits);
        out.push(tracked.push_token(tok, 0.0));
        tracked.phase = SeqPhase::Decoding;

        if let Some(reason) = self.finish_after_token(&tracked, tok) {
            self.release_holdings(pool_id, &shared_forks)?;
            self.stats.completed += 1;
            out.push(EngineEvent::Finished(tracked.respond(reason)));
            return Ok(());
        }
        self.active[idx] = Some(Active {
            tracked,
            state: SlotState::Decoding(pre.kv),
            pool_id,
            shared_forks,
            shared_tokens,
        });
        Ok(())
    }

    /// One batched decode step over all decoding sequences; pushes a
    /// `Token` event per sequence plus terminal events. Returns how many
    /// sequences finished.
    fn decode_step(&mut self, out: &mut Vec<EngineEvent>) -> crate::Result<usize> {
        let idxs: Vec<usize> = (0..self.active.len())
            .filter(|&i| {
                matches!(
                    self.active[i].as_ref().map(|a| &a.state),
                    Some(SlotState::Decoding(_))
                )
            })
            .collect();
        if idxs.is_empty() {
            return Ok(0);
        }
        let t0 = Instant::now();
        let tokens: Vec<i32> = idxs
            .iter()
            .map(|&i| self.active[i].as_ref().unwrap().tracked.next_token)
            .collect();

        // Borrow all selected slots mutably via split_at_mut-free take.
        let mut taken: Vec<Active> = idxs
            .iter()
            .map(|&i| self.active[i].take().unwrap())
            .collect();
        {
            let mut slot_refs: Vec<Option<&mut SeqKv>> = taken
                .iter_mut()
                .map(|a| match &mut a.state {
                    SlotState::Decoding(kv) => Some(kv),
                    SlotState::Prefilling(_) => {
                        unreachable!("taken slots are decoding by construction")
                    }
                })
                .collect();
            let logits = self.backend.decode(&tokens, &mut slot_refs)?;
            let vocab = self.backend.vocab();
            let dt = t0.elapsed().as_secs_f64() * 1e3;
            let batch_n = taken.len();
            self.stats.decode_steps += 1;
            self.stats.decode_batch_sum += batch_n as u64;
            // No pool.extend here: admission already reserved the full
            // prompt + max_new_tokens budget, so growing the accounting
            // per generated token would double-count — and, with the
            // radix cache retaining blocks, could spuriously exhaust the
            // pool mid-decode.
            for (bi, act) in taken.iter_mut().enumerate() {
                let tok = act.tracked.sampler.sample(&logits[bi * vocab..(bi + 1) * vocab]);
                act.tracked.decode_ms += dt / batch_n as f64;
                out.push(act.tracked.push_token(tok, dt / batch_n as f64));
                self.stats.decode_tokens += 1;
            }
        }
        // Retire finished sequences, return the rest to their slots.
        let mut done = 0;
        for (k, act) in taken.into_iter().enumerate() {
            let last = *act.tracked.output.last().unwrap();
            let SlotState::Decoding(ref kv) = act.state else {
                unreachable!("taken slots are decoding by construction")
            };
            let cache_full = kv.pos() >= self.backend.cache_len();
            let reason = self.finish_after_token(&act.tracked, last).or(if cache_full {
                Some(FinishReason::CacheFull)
            } else {
                None
            });
            match reason {
                Some(r) => {
                    self.release_holdings(act.pool_id, &act.shared_forks)?;
                    self.stats.completed += 1;
                    done += 1;
                    out.push(EngineEvent::Finished(act.tracked.respond(r)));
                }
                None => self.active[idxs[k]] = Some(act),
            }
        }
        Ok(done)
    }

    /// Sample peak resident cache bytes and the backend's cumulative
    /// page-decode counters with every slot in place. Called from
    /// [`Self::step`] after the prefill and decode phases so pure-prefill
    /// windows (where `decode_step` never runs) are covered too — chunked
    /// prefill is exactly when a sequence's cache grows.
    fn sample_kv_stats(&mut self) {
        let live: u64 = self
            .active
            .iter()
            .flatten()
            .map(|a| match &a.state {
                SlotState::Decoding(kv) => kv.resident_bytes() as u64,
                SlotState::Prefilling(seq) => seq.resident_bytes() as u64,
            })
            .sum();
        self.stats.kv_bytes_peak = self.stats.kv_bytes_peak.max(live);
        self.stats.kv_pages = self.backend.kv_page_stats();
    }

    /// Run one scheduling iteration (admit, one prefill chunk per
    /// prefilling sequence, then a decode slice). Returns the events the
    /// iteration produced, in emission order.
    pub fn step(&mut self) -> crate::Result<Vec<EngineEvent>> {
        self.stats.engine_steps += 1;
        let mut out = Vec::new();
        // Phase 1: admit while slots and KV blocks allow.
        while self.try_admit(&mut out)? {}
        // Phase 2: one chunk per prefilling sequence — prefill and decode
        // interleave instead of prefill running whole prompts to
        // completion first.
        for idx in 0..self.active.len() {
            self.advance_prefill(idx, &mut out)?;
        }
        self.sample_kv_stats();
        // Phase 3: a slice of decode steps.
        for _ in 0..self.cfg.decode_slice {
            let done = self.decode_step(&mut out)?;
            if done == 0
                && !self
                    .active
                    .iter()
                    .flatten()
                    .any(|a| matches!(a.state, SlotState::Decoding(_)))
            {
                break;
            }
            // Re-check prefill as soon as a slot freed up.
            if done > 0 && !self.queue.is_empty() {
                break;
            }
        }
        self.sample_kv_stats();
        Ok(out)
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.iter().all(Option::is_none)
    }

    /// Drive until all submitted work completes; returns the full event
    /// stream.
    pub fn run_until_idle_events(&mut self) -> crate::Result<Vec<EngineEvent>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Drive until all submitted work completes; returns the terminal
    /// responses (back-compat batch API over the event stream).
    pub fn run_until_idle(&mut self) -> crate::Result<Vec<Response>> {
        Ok(self
            .run_until_idle_events()?
            .into_iter()
            .filter_map(EngineEvent::into_finished)
            .collect())
    }
}

// ---------------------------------------------------------------------
// Threaded handle
// ---------------------------------------------------------------------

enum Msg {
    Submit(Request),
    Cancel(u64),
    Shutdown,
}

/// A worker thread owning an [`Engine`]; requests and cancels in,
/// [`EngineEvent`]s out.
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    pub rx: std::sync::Mutex<mpsc::Receiver<EngineEvent>>,
    join: Option<std::thread::JoinHandle<()>>,
    load: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    prefix_hit_tokens: std::sync::Arc<std::sync::atomic::AtomicU64>,
    kv_bytes_in_use: std::sync::Arc<std::sync::atomic::AtomicU64>,
    decoded_cache_hits: std::sync::Arc<std::sync::atomic::AtomicU64>,
    decoded_cache_misses: std::sync::Arc<std::sync::atomic::AtomicU64>,
    kv_format: &'static str,
    kv_policy: String,
}

impl EngineHandle {
    /// Spawn the engine loop on its own thread. `make_backend` runs on
    /// the worker thread (PJRT handles are not Send).
    pub fn spawn<F>(make_backend: F, cfg: EngineConfig, eos_token: i32) -> EngineHandle
    where
        F: FnOnce() -> crate::Result<Box<dyn ModelBackend>> + Send + 'static,
    {
        let kv_format = cfg.kv_format.name();
        let kv_policy = KvPolicy::format_layers(&cfg.kv_precision_policies);
        let (tx, rx_msg) = mpsc::channel::<Msg>();
        let (tx_ev, rx) = mpsc::channel::<EngineEvent>();
        let load = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let load2 = load.clone();
        let prefix_hit_tokens = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let pht2 = prefix_hit_tokens.clone();
        let kv_bytes_in_use = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let kvb2 = kv_bytes_in_use.clone();
        let decoded_cache_hits = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let dch2 = decoded_cache_hits.clone();
        let decoded_cache_misses = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let dcm2 = decoded_cache_misses.clone();
        let join = std::thread::spawn(move || {
            let backend = match make_backend() {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("engine backend init failed: {e:#}");
                    return;
                }
            };
            let mut engine = Engine::new(backend, cfg, eos_token);
            // Apply one control message; true means shut down.
            fn apply(engine: &mut Engine, tx_ev: &mpsc::Sender<EngineEvent>, msg: Msg) -> bool {
                match msg {
                    Msg::Submit(req) => {
                        if let Some(resp) = engine.submit(req) {
                            let _ = tx_ev.send(EngineEvent::Finished(resp));
                        }
                        false
                    }
                    Msg::Cancel(id) => {
                        match engine.cancel(id) {
                            Ok(Some(ev)) => {
                                let _ = tx_ev.send(ev);
                            }
                            Ok(None) => {} // already finished — no-op
                            Err(e) => eprintln!("engine cancel error: {e:#}"),
                        }
                        false
                    }
                    Msg::Shutdown => true,
                }
            }
            'run: loop {
                // Block for work only when idle; otherwise drain every
                // pending control message (a cancel burst must not wait
                // one scheduler step per message).
                if engine.idle() {
                    match rx_msg.recv() {
                        Ok(m) => {
                            if apply(&mut engine, &tx_ev, m) {
                                break 'run;
                            }
                        }
                        Err(_) => break 'run,
                    }
                }
                loop {
                    match rx_msg.try_recv() {
                        Ok(m) => {
                            if apply(&mut engine, &tx_ev, m) {
                                break 'run;
                            }
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => break 'run,
                    }
                }
                match engine.step() {
                    Ok(events) => {
                        for ev in events {
                            let _ = tx_ev.send(ev);
                        }
                    }
                    Err(e) => {
                        eprintln!("engine step error: {e:#}");
                        break;
                    }
                }
                load2.store(engine.load(), std::sync::atomic::Ordering::Relaxed);
                pht2.store(
                    engine.stats.prefix_hit_tokens,
                    std::sync::atomic::Ordering::Relaxed,
                );
                kvb2.store(
                    engine.kv_bytes_in_use() as u64,
                    std::sync::atomic::Ordering::Relaxed,
                );
                dch2.store(
                    engine.stats.kv_pages.cache_hits,
                    std::sync::atomic::Ordering::Relaxed,
                );
                dcm2.store(
                    engine.stats.kv_pages.cache_misses,
                    std::sync::atomic::Ordering::Relaxed,
                );
            }
        });
        EngineHandle {
            tx,
            rx: std::sync::Mutex::new(rx),
            join: Some(join),
            load,
            prefix_hit_tokens,
            kv_bytes_in_use,
            decoded_cache_hits,
            decoded_cache_misses,
            kv_format,
            kv_policy,
        }
    }

    pub fn submit(&self, req: Request) -> crate::Result<()> {
        self.tx
            .send(Msg::Submit(req))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))
    }

    /// Cancel a request by id. Fire-and-forget: the terminal
    /// `cancelled` event arrives on the event channel (nothing arrives
    /// when the request already finished).
    pub fn cancel(&self, id: u64) -> crate::Result<()> {
        self.tx
            .send(Msg::Cancel(id))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))
    }

    pub fn load(&self) -> usize {
        self.load.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// KV-cache storage format this worker was configured with.
    pub fn kv_format(&self) -> &'static str {
        self.kv_format
    }

    /// Precision policy spec this worker was configured with
    /// (`SINK/DIAG` or per-layer `l0:...;l1:...`).
    pub fn kv_policy(&self) -> &str {
        &self.kv_policy
    }

    /// Prompt tokens this worker served from its prefix cache so far.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// KV pool bytes currently referenced by this worker (sampled after
    /// each scheduler step).
    pub fn kv_bytes_in_use(&self) -> u64 {
        self.kv_bytes_in_use
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Cumulative decoded-page cache hits on this worker (page decodes
    /// served without re-dequantizing).
    pub fn decoded_cache_hits(&self) -> u64 {
        self.decoded_cache_hits
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Cumulative decoded-page cache misses on this worker.
    pub fn decoded_cache_misses(&self) -> u64 {
        self.decoded_cache_misses
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::runtime::host::HostBackend;

    fn engine() -> Engine {
        let cfg = EngineConfig { max_new_tokens: 8, ..Default::default() };
        Engine::new(Box::new(HostBackend::for_tests()), cfg, 5)
    }

    fn req(id: u64, len: usize, max_new: usize) -> Request {
        Request {
            id,
            tokens: (0..len).map(|i| ((i * 7) % 58) as i32 + 6).collect(),
            max_new_tokens: max_new,
            dma: false,
            ..Default::default()
        }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine();
        assert!(e.submit(req(1, 8, 4)).is_none());
        let resps = e.run_until_idle().unwrap();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].id, 1);
        assert!(resps[0].output.len() <= 4 && !resps[0].output.is_empty());
        assert!(matches!(resps[0].finish, FinishReason::Length | FinishReason::Eos));
        assert_eq!(e.stats.completed, 1);
    }

    #[test]
    fn event_stream_matches_terminal_response() {
        // Started precedes the first Token; the Token events replay the
        // final output exactly, with contiguous indices; TTFT is set.
        let mut e = engine();
        e.submit(req(1, 8, 4));
        let events = e.run_until_idle_events().unwrap();
        assert!(matches!(events[0], EngineEvent::Started { id: 1, .. }));
        let toks: Vec<i32> = events
            .iter()
            .filter_map(|ev| match ev {
                EngineEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        let idxs: Vec<usize> = events
            .iter()
            .filter_map(|ev| match ev {
                EngineEvent::Token { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(idxs, (0..toks.len()).collect::<Vec<_>>());
        let resp = events.last().unwrap().as_finished().expect("terminal event");
        assert_eq!(resp.output, toks);
        assert!(resp.ttft_ms > 0.0);
        assert!(resp.ttft_ms <= resp.queue_ms + resp.prefill_ms + resp.decode_ms + 1.0);
    }

    #[test]
    fn many_requests_batched() {
        let mut e = engine();
        for i in 0..6 {
            assert!(e.submit(req(i, 4 + i as usize, 4)).is_none());
        }
        let resps = e.run_until_idle().unwrap();
        assert_eq!(resps.len(), 6);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // With 4 slots and 6 requests, some decode steps must have been
        // batched (mean decode batch > 1).
        assert!(e.stats.mean_decode_batch() > 1.0, "{:?}", e.stats);
    }

    #[test]
    fn outputs_deterministic_vs_direct_backend() {
        // Engine batching must not change results: compare with a direct
        // prefill+decode loop on a fresh backend.
        let mut e = engine();
        e.submit(req(1, 6, 4));
        e.submit(req(2, 9, 4));
        let mut resps = e.run_until_idle().unwrap();
        resps.sort_by_key(|r| r.id);

        use crate::runtime::ModelBackend;
        let mut be = HostBackend::for_tests();
        for r in &resps {
            let rq = req(r.id, if r.id == 1 { 6 } else { 9 }, 4);
            let out = be.prefill(&rq.tokens, false, None).unwrap();
            let mut toks = vec![crate::model::argmax(&out.last_logits)];
            let mut slot = out.kv;
            while toks.len() < 4 && *toks.last().unwrap() != 5 {
                let lg = be
                    .decode(&[*toks.last().unwrap()], &mut [Some(&mut slot)])
                    .unwrap();
                toks.push(crate::model::argmax(&lg[..64]));
            }
            assert_eq!(r.output, toks, "request {}", r.id);
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_batch_invariant() {
        // temperature > 0: the same request produces the same tokens on
        // a fresh engine, alone or batched with other traffic.
        let sampled = |id: u64| Request {
            sampling: SamplingParams { temperature: 0.8, seed: 42, ..Default::default() },
            ..req(id, 8, 6)
        };
        let mut alone = engine();
        alone.submit(sampled(1));
        let solo = alone.run_until_idle().unwrap().remove(0);

        let mut busy = engine();
        busy.submit(req(7, 12, 6));
        busy.submit(sampled(1));
        busy.submit(req(8, 5, 6));
        let mut resps = busy.run_until_idle().unwrap();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].output, solo.output, "batching changed a seeded stream");

        // A different seed may (and here does) diverge.
        let mut other = engine();
        other.submit(Request {
            sampling: SamplingParams { temperature: 0.8, seed: 43, ..Default::default() },
            ..req(1, 8, 6)
        });
        let alt = other.run_until_idle().unwrap().remove(0);
        assert!(!alt.output.is_empty());
    }

    #[test]
    fn stop_tokens_truncate_generation() {
        // Learn the greedy output, then replay with its second token as
        // a stop token: generation must end there with finish "stop".
        let mut e = engine();
        e.submit(req(1, 8, 6));
        let full = e.run_until_idle().unwrap().remove(0);
        assert!(full.output.len() >= 2, "need >= 2 tokens: {:?}", full.output);
        let stop_tok = full.output[1];

        let mut e2 = engine();
        e2.submit(Request {
            sampling: SamplingParams { stop: vec![stop_tok], ..Default::default() },
            ..req(1, 8, 6)
        });
        let stopped = e2.run_until_idle().unwrap().remove(0);
        assert_eq!(stopped.finish, FinishReason::Stop);
        assert_eq!(stopped.output, full.output[..2].to_vec());
    }

    #[test]
    fn ignore_eos_generates_to_length() {
        // With ignore_eos the sequence runs to its token budget even if
        // EOS appears (force EOS-prone traffic by making EOS = the
        // greedy first token of a known request).
        let mut probe = engine();
        probe.submit(req(1, 8, 1));
        let first_tok = probe.run_until_idle().unwrap().remove(0).output[0];

        let mut e = Engine::new(
            Box::new(HostBackend::for_tests()),
            EngineConfig { max_new_tokens: 8, ..Default::default() },
            first_tok, // EOS == the first greedy token
        );
        e.submit(Request {
            sampling: SamplingParams { ignore_eos: true, ..Default::default() },
            ..req(1, 8, 4)
        });
        let r = e.run_until_idle().unwrap().remove(0);
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.output.len(), 4);
        assert_eq!(r.output[0], first_tok);
    }

    #[test]
    fn cancel_queued_request() {
        let mut e = engine();
        // Fill all 4 slots so a 5th stays queued.
        for i in 0..5 {
            e.submit(req(i, 8, 8));
        }
        let mut events = e.step().unwrap();
        let ev = e.cancel(4).unwrap().expect("queued request cancels");
        let resp = ev.as_finished().unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.output.is_empty());
        events.extend(e.run_until_idle_events().unwrap());
        // The cancelled id never started nor finished through the stream.
        assert!(!events.iter().any(|ev| ev.id() == 4));
        assert_eq!(e.stats.cancelled, 1);
        assert_eq!(e.stats.completed, 4);
    }

    #[test]
    fn cancel_mid_prefill_returns_pool_bytes() {
        let cfg = EngineConfig {
            max_new_tokens: 8,
            prefill_chunk: 16,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
        let bytes0 = e.kv_bytes_in_use();
        let free0 = e.kv_free_blocks();
        e.submit(req(1, 64, 4)); // 4 chunks of 16
        e.step().unwrap(); // admitted + first chunk only
        assert!(e.kv_bytes_in_use() > bytes0, "prefill holds pool bytes");
        let ev = e.cancel(1).unwrap().expect("active request cancels");
        assert_eq!(ev.as_finished().unwrap().finish, FinishReason::Cancelled);
        assert_eq!(e.kv_bytes_in_use(), bytes0, "pool bytes not returned");
        assert_eq!(e.kv_free_blocks(), free0);
        e.pool_check().unwrap();
        assert!(e.idle());
        // The engine keeps serving.
        e.submit(req(2, 8, 2));
        assert_eq!(e.run_until_idle().unwrap().len(), 1);
    }

    #[test]
    fn cancel_mid_decode_returns_pool_bytes() {
        // decode_slice 1 keeps the sequence mid-decode across steps;
        // ignore_eos keeps it from retiring early.
        let cfg = EngineConfig { max_new_tokens: 16, decode_slice: 1, ..Default::default() };
        let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
        let bytes0 = e.kv_bytes_in_use();
        e.submit(Request {
            sampling: SamplingParams { ignore_eos: true, ..Default::default() },
            ..req(1, 8, 16)
        });
        let evs = e.step().unwrap(); // admit + prefill + one decode step
        assert!(evs.iter().any(|ev| matches!(ev, EngineEvent::Token { .. })));
        assert!(!e.idle(), "still decoding");
        let ev = e.cancel(1).unwrap().expect("decoding request cancels");
        let resp = ev.as_finished().unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(!resp.output.is_empty(), "partial output is returned");
        assert_eq!(e.kv_bytes_in_use(), bytes0);
        e.pool_check().unwrap();
    }

    #[test]
    fn cancel_unknown_id_is_noop() {
        let mut e = engine();
        assert!(e.cancel(99).unwrap().is_none());
        e.submit(req(1, 8, 2));
        e.run_until_idle().unwrap();
        // Already finished: also a no-op.
        assert!(e.cancel(1).unwrap().is_none());
        assert_eq!(e.stats.cancelled, 0);
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        // A long prompt admitted while another sequence decodes must not
        // be prefilled in one scheduler step: its chunks spread over
        // several steps, and the decoding sequence keeps making progress
        // between them.
        let cfg = EngineConfig {
            max_new_tokens: 24,
            prefill_chunk: 16,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
        let mut resps = Vec::new();
        let finished = |evs: Vec<EngineEvent>| {
            evs.into_iter().filter_map(EngineEvent::into_finished).collect::<Vec<_>>()
        };
        // Short prompt, long generation: becomes the decoder.
        e.submit(req(1, 4, 24));
        resps.extend(finished(e.step().unwrap()));
        let decoded_before = e.stats.decode_tokens;
        assert!(decoded_before > 0);
        // Long prompt arrives: 64 tokens = 4 chunks of 16.
        e.submit(req(2, 64, 2));
        let chunks_before = e.stats.prefill_chunks;
        resps.extend(finished(e.step().unwrap()));
        assert_eq!(
            e.stats.prefill_chunks - chunks_before,
            1,
            "exactly one chunk per step per prefilling sequence"
        );
        // The decoder advanced within the same step.
        assert!(e.stats.decode_tokens > decoded_before);
        // Three more steps finish the prefill.
        resps.extend(finished(e.step().unwrap()));
        resps.extend(finished(e.step().unwrap()));
        resps.extend(finished(e.step().unwrap()));
        assert_eq!(e.stats.prefill_tokens, 4 + 64);
        assert!(e.stats.mean_chunks_per_step() > 0.0);
        resps.extend(e.run_until_idle().unwrap());
        assert_eq!(resps.len(), 2);
    }

    #[test]
    fn quantized_cache_engine_round_trip() {
        // The engine serves end to end over each quantized format; the
        // admission accounting reflects the format's bytes/token.
        for format in [KvFormat::Dual, KvFormat::Mxfp8, KvFormat::Nvfp4] {
            let cfg = EngineConfig {
                max_new_tokens: 4,
                kv_format: format,
                kv_precision_policies: vec![crate::kvquant::KvPolicy { sink: 16, diag: 16 }],
                ..Default::default()
            };
            let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
            for i in 0..3 {
                assert!(e.submit(req(i, 8, 4)).is_none(), "{format:?}");
            }
            let resps = e.run_until_idle().unwrap();
            assert_eq!(resps.len(), 3, "{format:?}");
            for r in &resps {
                assert!(!r.output.is_empty(), "{format:?} req {}", r.id);
            }
            assert!(e.stats.kv_bytes_per_token < e.stats.kv_f32_bytes_per_token);
            assert!(e.stats.kv_pages.total() > 0, "{format:?}");
            assert!(e.stats.kv_bytes_peak > 0, "{format:?}");
        }
    }

    #[test]
    fn threads_do_not_change_token_streams() {
        // The --threads determinism contract: a multi-request workload
        // (greedy and seeded-sampled, f32 and quantized caches) produces
        // the identical per-request token streams at 1 and 4 threads.
        for format in [KvFormat::F32, KvFormat::Dual] {
            let run = |threads: usize| {
                let cfg = EngineConfig {
                    max_new_tokens: 8,
                    kv_format: format,
                    kv_precision_policies: vec![crate::kvquant::KvPolicy {
                        sink: 16,
                        diag: 16,
                    }],
                    threads,
                    ..Default::default()
                };
                let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
                for i in 0..6u64 {
                    let mut r = req(i, 4 + i as usize, 8);
                    if i % 2 == 1 {
                        r.sampling = SamplingParams {
                            temperature: 0.8,
                            seed: 42 + i,
                            ..Default::default()
                        };
                        r.sampling.ignore_eos = true;
                    }
                    assert!(e.submit(r).is_none());
                }
                let mut resps = e.run_until_idle().unwrap();
                resps.sort_by_key(|r| r.id);
                resps.into_iter().map(|r| r.output).collect::<Vec<_>>()
            };
            let serial = run(1);
            let threaded = run(4);
            assert_eq!(serial, threaded, "{format:?} token streams diverged");
        }
    }

    #[test]
    fn prefix_cache_skips_shared_prefill() {
        // Same prompt twice through a prefix-cached quantized engine: the
        // second request prefills only the last chunk and produces the
        // same tokens.
        let prompt_len = 48usize;
        let mk = |prefix_cache: bool| EngineConfig {
            max_new_tokens: 4,
            kv_format: KvFormat::Dual,
            prefill_chunk: 16,
            prefix_cache,
            kv_precision_policies: vec![crate::kvquant::KvPolicy { sink: 16, diag: 16 }],
            ..Default::default()
        };
        let mut cold = Engine::new(Box::new(HostBackend::for_tests()), mk(false), 5);
        cold.submit(req(1, prompt_len, 4));
        let cold_resps = cold.run_until_idle().unwrap();

        let mut e = Engine::new(Box::new(HostBackend::for_tests()), mk(true), 5);
        e.submit(req(1, prompt_len, 4));
        let first = e.run_until_idle().unwrap();
        assert_eq!(first[0].output, cold_resps[0].output);
        assert_eq!(e.stats.prefill_tokens, prompt_len as u64);
        assert_eq!(e.stats.prefix_hit_tokens, 0);
        // 48 tokens = 3 pages donated to the cache.
        assert_eq!(e.prefix_cache_pages(), 3);

        e.submit(req(2, prompt_len, 4));
        let second = e.run_until_idle().unwrap();
        assert_eq!(second[0].output, cold_resps[0].output, "warm run diverged");
        // Sharing is capped inside the prompt: 32 of 48 tokens shared,
        // the final chunk prefilled.
        assert_eq!(e.stats.prefix_hit_tokens, 32);
        assert_eq!(e.stats.prefix_hits, 1);
        assert_eq!(e.stats.prefill_tokens, prompt_len as u64 + 16);
    }

    #[test]
    fn prefix_cache_never_crosses_attention_modes() {
        // Pages prefilled under native attention must not seed a DMA-mode
        // request with the same tokens (and vice versa): first-chunk
        // hidden states differ between the modes.
        let cfg = EngineConfig {
            max_new_tokens: 4,
            kv_format: KvFormat::Dual,
            prefill_chunk: 16,
            prefix_cache: true,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
        let tokens: Vec<i32> = (0..48).map(|i| ((i * 7) % 58) as i32 + 6).collect();
        let mk = |id: u64, dma: bool| Request {
            id,
            tokens: tokens.clone(),
            max_new_tokens: 4,
            dma,
            ..Default::default()
        };
        e.submit(mk(1, false));
        e.run_until_idle().unwrap();
        // Same tokens, other mode: no hit.
        e.submit(mk(2, true));
        e.run_until_idle().unwrap();
        assert_eq!(e.stats.prefix_hit_tokens, 0, "cross-mode prefix hit");
        // Same tokens, same mode as the second request: hits.
        e.submit(mk(3, true));
        e.run_until_idle().unwrap();
        assert_eq!(e.stats.prefix_hit_tokens, 32);
    }

    #[test]
    fn prefix_cache_evicts_under_pressure() {
        // Fill the cache with disjoint prompts, then admit requests whose
        // budgets need the blocks back: eviction must free them and every
        // request still completes.
        let cfg = EngineConfig {
            max_new_tokens: 4,
            kv_format: KvFormat::Dual,
            prefill_chunk: 16,
            prefix_cache: true,
            queue_limit: 64,
            ..Default::default()
        };
        // Dual format: 111 pool blocks. 40 disjoint 60-token prompts
        // retain 3 cache pages each — the cache alone would need 120
        // blocks, so admission must evict LRU pages along the way.
        let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
        let mut resps = Vec::new();
        for i in 0..40u64 {
            let mut r = req(i, 60, 4);
            // Disjoint prompts: no sharing, maximal cache churn.
            for t in r.tokens.iter_mut() {
                *t = ((*t as u64 * (i + 3)) % 58) as i32 + 6;
            }
            assert!(e.submit(r).is_none());
            resps.extend(
                e.step().unwrap().into_iter().filter_map(EngineEvent::into_finished),
            );
        }
        resps.extend(e.run_until_idle().unwrap());
        assert_eq!(resps.len(), 40);
        assert!(e.idle());
        // Eviction really ran: fewer pages resident than were donated.
        assert!(e.prefix_cache_pages() < 120, "{}", e.prefix_cache_pages());
        // The pool must not have leaked: all blocks either free or held
        // by resident cache pages.
        assert!(e.pool.check_invariants().is_ok());
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut e = engine();
        let r = e.submit(req(1, 200, 4)); // cache is 96 in the test backend
        let resp = r.expect("should reject");
        assert_eq!(resp.finish, FinishReason::Rejected);
        assert!(resp.error.unwrap().contains("exceeds cache"));
    }

    #[test]
    fn rejects_empty_prompt() {
        let mut e = engine();
        let resp =
            e.submit(Request { id: 1, tokens: vec![], max_new_tokens: 2, ..Default::default() });
        assert_eq!(resp.unwrap().finish, FinishReason::Rejected);
    }

    #[test]
    fn queue_limit_enforced() {
        let mut e = engine();
        e.cfg.queue_limit = 2;
        assert!(e.submit(req(1, 4, 2)).is_none());
        assert!(e.submit(req(2, 4, 2)).is_none());
        let resp = e.submit(req(3, 4, 2)).expect("queue full");
        assert_eq!(resp.finish, FinishReason::Rejected);
    }

    #[test]
    fn metrics_accumulate() {
        let mut e = engine();
        e.submit(req(1, 8, 4));
        e.submit(req(2, 8, 4));
        e.run_until_idle().unwrap();
        assert_eq!(e.stats.completed, 2);
        assert_eq!(e.stats.prefill_tokens, 16);
        assert!(e.stats.prefill_chunks >= 2);
        assert!(e.stats.engine_steps > 0);
        assert!(e.stats.decode_tokens > 0);
    }

    #[test]
    fn threaded_handle_round_trip() {
        let cfg = EngineConfig { max_new_tokens: 4, ..Default::default() };
        let h = EngineHandle::spawn(
            || Ok(Box::new(HostBackend::for_tests()) as Box<dyn crate::runtime::ModelBackend>),
            cfg,
            5,
        );
        assert_eq!(h.kv_policy(), "128/128");
        for i in 0..3 {
            h.submit(req(i, 6, 3)).unwrap();
        }
        let mut got = 0;
        while got < 3 {
            let ev = h
                .rx
                .lock()
                .unwrap()
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap();
            if let EngineEvent::Finished(r) = ev {
                assert!(!r.output.is_empty());
                got += 1;
            }
        }
        h.shutdown();
    }

    #[test]
    fn threaded_handle_cancel_round_trip() {
        // decode_slice 1: one token per scheduler step, so the cancel
        // sent at the first token has dozens of steps of margin.
        let cfg = EngineConfig { max_new_tokens: 64, decode_slice: 1, ..Default::default() };
        let h = EngineHandle::spawn(
            || Ok(Box::new(HostBackend::for_tests()) as Box<dyn crate::runtime::ModelBackend>),
            cfg,
            5,
        );
        h.submit(Request {
            sampling: SamplingParams { ignore_eos: true, ..Default::default() },
            ..req(1, 8, 60)
        })
        .unwrap();
        // Wait for the first token, then cancel.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        let mut cancelled = false;
        let mut finish = None;
        while std::time::Instant::now() < deadline {
            let ev = h
                .rx
                .lock()
                .unwrap()
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap();
            match ev {
                EngineEvent::Token { .. } if !cancelled => {
                    h.cancel(1).unwrap();
                    cancelled = true;
                }
                EngineEvent::Finished(r) => {
                    finish = Some(r);
                    break;
                }
                _ => {}
            }
        }
        let r = finish.expect("terminal event after cancel");
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(!r.output.is_empty());
        assert!(r.output.len() < 60);
        h.shutdown();
    }
}
