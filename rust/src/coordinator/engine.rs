//! The serving engine: continuous batching over a [`ModelBackend`].
//!
//! Policy (vLLM-style, prefill-prioritized):
//!
//! 1. While batch slots and KV blocks are free, admit a queued request
//!    and run its prefill (one sequence at a time — prefill of different
//!    lengths cannot share a bucketed executable).
//! 2. Run up to `decode_slice` batched decode steps over all active
//!    slots, then loop back to (1) so newly arrived prompts are not
//!    starved behind long generations.
//! 3. A sequence retires on EOS, its token budget, or cache capacity.
//!
//! Admission uses the paged [`BlockPool`] accounting: a request is only
//! admitted when its prompt + token budget fit in free KV blocks, so
//! decode can never deadlock on cache space.

use super::request::{FinishReason, Request, Response, SeqPhase, Tracked};
use crate::config::EngineConfig;
use crate::kvcache::{BlockPool, SeqKv, SlotCache};
use crate::kvquant::{KvFormat, KvQuantConfig, QuantSlotKv, PAGE_TOKENS};
use crate::model::argmax;
use crate::runtime::ModelBackend;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

struct Active {
    tracked: Tracked,
    slot: SeqKv,
}

enum PrefillOutcome {
    /// A sequence was admitted and is now decoding.
    Started,
    /// A sequence finished (or failed) during prefill.
    Finished(Response),
    /// Nothing admissible right now.
    NoWork,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub completed: u64,
    pub rejected: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    /// Admission accounting cost of one cached token in bytes at the
    /// configured `kv_format` (all layers/heads, K + V).
    pub kv_bytes_per_token: u64,
    /// The same cost at f32 — `kv_bytes_per_token / kv_f32_bytes_per_token`
    /// is the cache compression the format buys.
    pub kv_f32_bytes_per_token: u64,
    /// Peak resident bytes of all active sequence caches.
    pub kv_bytes_peak: u64,
    /// Per-precision page-decode hits (quantized caches only).
    pub kv_pages: crate::metrics::KvPageStats,
}

impl EngineStats {
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_batch_sum as f64 / self.decode_steps as f64
        }
    }

    /// Cache bytes-per-token compression vs f32 (1.0 for the f32 cache).
    pub fn kv_compression(&self) -> f64 {
        crate::metrics::compression_ratio(
            self.kv_f32_bytes_per_token as usize,
            self.kv_bytes_per_token as usize,
        )
    }
}

pub struct Engine {
    pub cfg: EngineConfig,
    backend: Box<dyn ModelBackend>,
    queue: VecDeque<Tracked>,
    active: Vec<Option<Active>>,
    pool: BlockPool,
    eos_token: i32,
    /// Quantized-cache layout, `None` for the f32 cache.
    kv_quant: Option<KvQuantConfig>,
    /// `(n_layers, n_kv_heads, d_head)` from the backend.
    kv_dims: (usize, usize, usize),
    pub stats: EngineStats,
}

impl Engine {
    pub fn new(backend: Box<dyn ModelBackend>, cfg: EngineConfig, eos_token: i32) -> Engine {
        let max_slots = backend.decode_buckets().into_iter().max().unwrap_or(1);
        // Format-aware KV accounting: the physical budget is what the f32
        // slots would occupy (max_slots full-length caches); cheaper
        // formats get proportionally more 16-token admission blocks.
        let block_tokens = PAGE_TOKENS;
        let (nl, hk, dh) = backend.kv_dims();
        let f32_bpt = 2 * nl * hk * dh * 4;
        let bpt = 2 * nl * hk * cfg.kv_format.row_bytes(dh);
        let budget = max_slots * backend.cache_len() * f32_bpt;
        let kv_quant = match cfg.kv_format {
            KvFormat::F32 => None,
            format => Some(KvQuantConfig {
                format,
                page_tokens: block_tokens,
                policy: cfg.kv_precision_policy,
            }),
        };
        let stats = EngineStats {
            kv_bytes_per_token: bpt as u64,
            kv_f32_bytes_per_token: f32_bpt as u64,
            ..Default::default()
        };
        Engine {
            cfg,
            pool: BlockPool::with_byte_budget(budget, block_tokens, bpt),
            active: (0..max_slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            backend,
            eos_token,
            kv_quant,
            kv_dims: (nl, hk, dh),
            stats,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Number of requests currently queued + active (router load signal).
    pub fn load(&self) -> usize {
        self.queue.len() + self.active.iter().flatten().count()
    }

    /// Submit a request; returns an immediate rejection response when
    /// admission is impossible (prompt too long / queue full).
    pub fn submit(&mut self, req: Request) -> Option<Response> {
        if self.queue.len() >= self.cfg.queue_limit {
            self.stats.rejected += 1;
            return Some(Response {
                id: req.id,
                output: vec![],
                finish: FinishReason::Rejected,
                queue_ms: 0.0,
                prefill_ms: 0.0,
                decode_ms: 0.0,
                error: Some("queue full".into()),
            });
        }
        let budget = req.tokens.len() + req.max_new_tokens.min(self.cfg.max_new_tokens);
        if req.tokens.is_empty() || budget > self.backend.cache_len() {
            self.stats.rejected += 1;
            return Some(Response {
                id: req.id,
                output: vec![],
                finish: FinishReason::Rejected,
                queue_ms: 0.0,
                prefill_ms: 0.0,
                decode_ms: 0.0,
                error: Some(format!(
                    "prompt+budget {budget} exceeds cache {}",
                    self.backend.cache_len()
                )),
            });
        }
        self.queue.push_back(Tracked::new(req));
        None
    }

    fn free_slot(&self) -> Option<usize> {
        self.active.iter().position(Option::is_none)
    }

    /// Try to admit + prefill one queued request.
    fn try_prefill(&mut self) -> crate::Result<PrefillOutcome> {
        let Some(slot_idx) = self.free_slot() else {
            return Ok(PrefillOutcome::NoWork);
        };
        // Admission: the head request must fit its full token budget.
        let Some(head) = self.queue.front() else {
            return Ok(PrefillOutcome::NoWork);
        };
        let budget =
            head.req.tokens.len() + head.req.max_new_tokens.min(self.cfg.max_new_tokens);
        if !self.pool.can_admit(budget) {
            return Ok(PrefillOutcome::NoWork);
        }
        let mut tracked = self.queue.pop_front().unwrap();
        tracked.queue_ms = tracked.enqueued.elapsed().as_secs_f64() * 1e3;
        self.pool.allocate(tracked.req.id, budget)?;

        let t0 = Instant::now();
        let out = match self.backend.prefill(&tracked.req.tokens, tracked.req.dma) {
            Ok(o) => o,
            Err(e) => {
                self.pool.release(tracked.req.id)?;
                self.stats.rejected += 1;
                let mut resp = tracked.respond(FinishReason::Rejected);
                resp.error = Some(e.to_string());
                return Ok(PrefillOutcome::Finished(resp));
            }
        };
        tracked.prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.prefill_tokens += tracked.req.tokens.len() as u64;

        // First generated token comes from the prefill logits.
        let tok = argmax(&out.last_logits);
        tracked.output.push(tok);
        tracked.next_token = tok;
        tracked.phase = SeqPhase::Decoding;

        // Single-token request or instant EOS finishes immediately.
        let max_new = tracked.req.max_new_tokens.min(self.cfg.max_new_tokens);
        if tok == self.eos_token || max_new <= 1 {
            self.pool.release(tracked.req.id)?;
            self.stats.completed += 1;
            let reason = if tok == self.eos_token {
                FinishReason::Eos
            } else {
                FinishReason::Length
            };
            return Ok(PrefillOutcome::Finished(tracked.respond(reason)));
        }
        // Quantize the prefill cache into the paged store when the
        // configured format asks for one; decode then runs entirely over
        // quantized pages.
        let slot = match &self.kv_quant {
            None => SeqKv::F32(out.slot),
            Some(qcfg) => {
                let (nl, hk, dh) = self.kv_dims;
                let layout = SlotCache::new(nl, hk, self.backend.cache_len(), dh);
                SeqKv::Quant(QuantSlotKv::from_slot(&out.slot, &layout, *qcfg))
            }
        };
        self.active[slot_idx] = Some(Active { tracked, slot });
        Ok(PrefillOutcome::Started)
    }

    /// One batched decode step over all active sequences; returns any
    /// completed responses.
    fn decode_step(&mut self) -> crate::Result<Vec<Response>> {
        let idxs: Vec<usize> = (0..self.active.len())
            .filter(|&i| self.active[i].is_some())
            .collect();
        if idxs.is_empty() {
            return Ok(vec![]);
        }
        let t0 = Instant::now();
        let tokens: Vec<i32> = idxs
            .iter()
            .map(|&i| self.active[i].as_ref().unwrap().tracked.next_token)
            .collect();

        // Borrow all selected slots mutably via split_at_mut-free take.
        let mut taken: Vec<Active> = idxs
            .iter()
            .map(|&i| self.active[i].take().unwrap())
            .collect();
        {
            let mut slot_refs: Vec<Option<&mut SeqKv>> =
                taken.iter_mut().map(|a| Some(&mut a.slot)).collect();
            let logits = self.backend.decode(&tokens, &mut slot_refs)?;
            let vocab = self.backend.vocab();
            let dt = t0.elapsed().as_secs_f64() * 1e3;
            let batch_n = taken.len();
            self.stats.decode_steps += 1;
            self.stats.decode_batch_sum += batch_n as u64;
            for (bi, act) in taken.iter_mut().enumerate() {
                let tok = argmax(&logits[bi * vocab..(bi + 1) * vocab]);
                act.tracked.output.push(tok);
                act.tracked.next_token = tok;
                act.tracked.decode_ms += dt / batch_n as f64;
                self.stats.decode_tokens += 1;
                self.pool.extend(act.tracked.req.id, 1)?;
            }
        }
        // Cache-byte and page-precision reporting.
        let live: u64 = taken.iter().map(|a| a.slot.resident_bytes() as u64).sum();
        self.stats.kv_bytes_peak = self.stats.kv_bytes_peak.max(live);
        self.stats.kv_pages = self.backend.kv_page_stats();

        // Retire finished sequences, return the rest to their slots.
        let mut done = Vec::new();
        for (k, act) in taken.into_iter().enumerate() {
            let max_new = act.tracked.req.max_new_tokens.min(self.cfg.max_new_tokens);
            let last = *act.tracked.output.last().unwrap();
            let cache_full = act.slot.pos() >= self.backend.cache_len();
            let reason = if last == self.eos_token {
                Some(FinishReason::Eos)
            } else if act.tracked.output.len() >= max_new {
                Some(FinishReason::Length)
            } else if cache_full {
                Some(FinishReason::CacheFull)
            } else {
                None
            };
            match reason {
                Some(r) => {
                    self.pool.release(act.tracked.req.id)?;
                    self.stats.completed += 1;
                    done.push(act.tracked.respond(r));
                }
                None => self.active[idxs[k]] = Some(act),
            }
        }
        Ok(done)
    }

    /// Run one scheduling iteration (prefill-first, then a decode slice).
    /// Returns completed responses.
    pub fn step(&mut self) -> crate::Result<Vec<Response>> {
        let mut out = Vec::new();
        // Phase 1: admit + prefill while possible.
        loop {
            match self.try_prefill()? {
                PrefillOutcome::Started => {}
                PrefillOutcome::Finished(resp) => out.push(resp),
                PrefillOutcome::NoWork => break,
            }
        }
        // Phase 2: a slice of decode steps.
        for _ in 0..self.cfg.decode_slice {
            let done = self.decode_step()?;
            let empty = done.is_empty();
            out.extend(done);
            if empty && self.active.iter().all(Option::is_none) {
                break;
            }
            // Re-check prefill as soon as a slot freed up.
            if !empty && !self.queue.is_empty() {
                break;
            }
        }
        Ok(out)
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.iter().all(Option::is_none)
    }

    /// Drive until all submitted work completes; returns all responses.
    pub fn run_until_idle(&mut self) -> crate::Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// Threaded handle
// ---------------------------------------------------------------------

enum Msg {
    Submit(Request),
    Shutdown,
}

/// A worker thread owning an [`Engine`]; requests in, responses out.
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    pub rx: std::sync::Mutex<mpsc::Receiver<Response>>,
    join: Option<std::thread::JoinHandle<()>>,
    load: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    kv_format: &'static str,
}

impl EngineHandle {
    /// Spawn the engine loop on its own thread. `make_backend` runs on
    /// the worker thread (PJRT handles are not Send).
    pub fn spawn<F>(make_backend: F, cfg: EngineConfig, eos_token: i32) -> EngineHandle
    where
        F: FnOnce() -> crate::Result<Box<dyn ModelBackend>> + Send + 'static,
    {
        let kv_format = cfg.kv_format.name();
        let (tx, rx_msg) = mpsc::channel::<Msg>();
        let (tx_resp, rx) = mpsc::channel::<Response>();
        let load = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let load2 = load.clone();
        let join = std::thread::spawn(move || {
            let backend = match make_backend() {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("engine backend init failed: {e:#}");
                    return;
                }
            };
            let mut engine = Engine::new(backend, cfg, eos_token);
            loop {
                // Drain control messages; block only when idle.
                let msg = if engine.idle() {
                    match rx_msg.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break,
                    }
                } else {
                    match rx_msg.try_recv() {
                        Ok(m) => Some(m),
                        Err(mpsc::TryRecvError::Empty) => None,
                        Err(mpsc::TryRecvError::Disconnected) => break,
                    }
                };
                match msg {
                    Some(Msg::Submit(req)) => {
                        if let Some(resp) = engine.submit(req) {
                            let _ = tx_resp.send(resp);
                        }
                    }
                    Some(Msg::Shutdown) => break,
                    None => {}
                }
                match engine.step() {
                    Ok(resps) => {
                        for r in resps {
                            let _ = tx_resp.send(r);
                        }
                    }
                    Err(e) => {
                        eprintln!("engine step error: {e:#}");
                        break;
                    }
                }
                load2.store(engine.load(), std::sync::atomic::Ordering::Relaxed);
            }
        });
        EngineHandle { tx, rx: std::sync::Mutex::new(rx), join: Some(join), load, kv_format }
    }

    pub fn submit(&self, req: Request) -> crate::Result<()> {
        self.tx
            .send(Msg::Submit(req))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))
    }

    pub fn load(&self) -> usize {
        self.load.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// KV-cache storage format this worker was configured with.
    pub fn kv_format(&self) -> &'static str {
        self.kv_format
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::host::HostBackend;

    fn engine() -> Engine {
        let cfg = EngineConfig { max_new_tokens: 8, ..Default::default() };
        Engine::new(Box::new(HostBackend::for_tests()), cfg, 5)
    }

    fn req(id: u64, len: usize, max_new: usize) -> Request {
        Request {
            id,
            tokens: (0..len).map(|i| ((i * 7) % 58) as i32 + 6).collect(),
            max_new_tokens: max_new,
            dma: false,
        }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine();
        assert!(e.submit(req(1, 8, 4)).is_none());
        let resps = e.run_until_idle().unwrap();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].id, 1);
        assert!(resps[0].output.len() <= 4 && !resps[0].output.is_empty());
        assert!(matches!(resps[0].finish, FinishReason::Length | FinishReason::Eos));
        assert_eq!(e.stats.completed, 1);
    }

    #[test]
    fn many_requests_batched() {
        let mut e = engine();
        for i in 0..6 {
            assert!(e.submit(req(i, 4 + i as usize, 4)).is_none());
        }
        let resps = e.run_until_idle().unwrap();
        assert_eq!(resps.len(), 6);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // With 4 slots and 6 requests, some decode steps must have been
        // batched (mean decode batch > 1).
        assert!(e.stats.mean_decode_batch() > 1.0, "{:?}", e.stats);
    }

    #[test]
    fn outputs_deterministic_vs_direct_backend() {
        // Engine batching must not change results: compare with a direct
        // prefill+decode loop on a fresh backend.
        let mut e = engine();
        e.submit(req(1, 6, 4));
        e.submit(req(2, 9, 4));
        let mut resps = e.run_until_idle().unwrap();
        resps.sort_by_key(|r| r.id);

        use crate::runtime::ModelBackend;
        let mut be = HostBackend::for_tests();
        for r in &resps {
            let rq = req(r.id, if r.id == 1 { 6 } else { 9 }, 4);
            let out = be.prefill(&rq.tokens, false).unwrap();
            let mut toks = vec![crate::model::argmax(&out.last_logits)];
            let mut slot = SeqKv::F32(out.slot);
            while toks.len() < 4 && *toks.last().unwrap() != 5 {
                let lg = be
                    .decode(&[*toks.last().unwrap()], &mut [Some(&mut slot)])
                    .unwrap();
                toks.push(crate::model::argmax(&lg[..64]));
            }
            assert_eq!(r.output, toks, "request {}", r.id);
        }
    }

    #[test]
    fn quantized_cache_engine_round_trip() {
        // The engine serves end to end over each quantized format; the
        // admission accounting reflects the format's bytes/token.
        for format in [KvFormat::Dual, KvFormat::Mxfp8, KvFormat::Nvfp4] {
            let cfg = EngineConfig {
                max_new_tokens: 4,
                kv_format: format,
                kv_precision_policy: crate::kvquant::KvPolicy { sink: 16, diag: 16 },
                ..Default::default()
            };
            let mut e = Engine::new(Box::new(HostBackend::for_tests()), cfg, 5);
            for i in 0..3 {
                assert!(e.submit(req(i, 8, 4)).is_none(), "{format:?}");
            }
            let resps = e.run_until_idle().unwrap();
            assert_eq!(resps.len(), 3, "{format:?}");
            for r in &resps {
                assert!(!r.output.is_empty(), "{format:?} req {}", r.id);
            }
            assert!(e.stats.kv_bytes_per_token < e.stats.kv_f32_bytes_per_token);
            assert!(e.stats.kv_pages.total() > 0, "{format:?}");
            assert!(e.stats.kv_bytes_peak > 0, "{format:?}");
        }
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut e = engine();
        let r = e.submit(req(1, 200, 4)); // cache is 96 in the test backend
        let resp = r.expect("should reject");
        assert_eq!(resp.finish, FinishReason::Rejected);
        assert!(resp.error.unwrap().contains("exceeds cache"));
    }

    #[test]
    fn rejects_empty_prompt() {
        let mut e = engine();
        let resp = e.submit(Request { id: 1, tokens: vec![], max_new_tokens: 2, dma: false });
        assert_eq!(resp.unwrap().finish, FinishReason::Rejected);
    }

    #[test]
    fn queue_limit_enforced() {
        let mut e = engine();
        e.cfg.queue_limit = 2;
        assert!(e.submit(req(1, 4, 2)).is_none());
        assert!(e.submit(req(2, 4, 2)).is_none());
        let resp = e.submit(req(3, 4, 2)).expect("queue full");
        assert_eq!(resp.finish, FinishReason::Rejected);
    }

    #[test]
    fn metrics_accumulate() {
        let mut e = engine();
        e.submit(req(1, 8, 4));
        e.submit(req(2, 8, 4));
        e.run_until_idle().unwrap();
        assert_eq!(e.stats.completed, 2);
        assert_eq!(e.stats.prefill_tokens, 16);
        assert!(e.stats.decode_tokens > 0);
    }

    #[test]
    fn threaded_handle_round_trip() {
        let cfg = EngineConfig { max_new_tokens: 4, ..Default::default() };
        let h = EngineHandle::spawn(
            || Ok(Box::new(HostBackend::for_tests()) as Box<dyn crate::runtime::ModelBackend>),
            cfg,
            5,
        );
        for i in 0..3 {
            h.submit(req(i, 6, 3)).unwrap();
        }
        let mut got = 0;
        while got < 3 {
            let r = h.rx.lock().unwrap().recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert!(!r.output.is_empty());
            got += 1;
        }
        h.shutdown();
    }
}
